//! Plan cache: memoized `(algorithm, p, partition, dtype) → Arc<Plan>`.
//!
//! The paper's Algorithm 1/2 schedules are pure functions of
//! `(p, partition, skip scheme)` — yet the pre-engine code regenerated
//! them on every collective call. For one-shot benches that is noise; for
//! the ROADMAP's serving workload (thousands of repeated collectives per
//! second through one [`crate::engine::CollectiveEngine`]) it is pure
//! waste on the submission path. A [`PlanCache`] memoizes built plans
//! behind `Arc`s so repeated collectives pay one hash lookup, and both the
//! engine's submission path and every [`crate::coordinator::Communicator`]
//! route their schedules through one.
//!
//! Keys carry a 64-bit partition *fingerprint*
//! ([`crate::datatypes::BlockPartition::fingerprint`]) rather than the
//! whole offset vector; every hit verifies the stored partition against
//! the requested one, so a fingerprint collision degrades to a (counted)
//! miss instead of ever serving a wrong schedule.
//!
//! Hit/miss counters are surfaced two ways: globally per cache
//! ([`PlanCache::stats`], what `ccoll serve` and the engine report) and
//! per rank through `transport::Counters::{plan_hits, plan_misses}`
//! (credited by the communicator, aggregated by
//! [`crate::coordinator::RunMetrics`]).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::datatypes::{BlockPartition, DType};
use crate::schedule::Schedule;

/// A fully-resolved execution plan: the schedule plus the partition it was
/// built for, shared behind one `Arc` so every rank of every repeated
/// collective reuses a single allocation.
#[derive(Debug)]
pub struct Plan {
    pub schedule: Schedule,
    pub part: BlockPartition,
    /// Per-(round, rank) zero-copy eligibility, proven once here by the
    /// analysis aliasing pass; executors consult it instead of
    /// recomputing the block-overlap test on every step.
    pub tiers: crate::analysis::TierMap,
}

impl Plan {
    pub fn new(schedule: Schedule, part: BlockPartition) -> Self {
        let tiers = crate::analysis::tier_map(&schedule);
        Self { schedule, part, tiers }
    }
}

/// Cache key — what a schedule is a pure function of, plus the dtype (the
/// schedule itself is dtype-independent, but plans are handed to typed
/// executors; keying by dtype keeps one cached plan from pinning another
/// dtype's partition object and makes the counters per-dtype honest).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// Canonical algorithm name (e.g. `allreduce:halving-up`,
    /// `binomial-scatter:3`) — free-form so non-`Algorithm` schedules
    /// (rooted scatter/gather trees) can participate. `Arc<str>` so
    /// steady-state callers (communicator, engine) key repeated lookups
    /// with a refcount bump instead of a fresh `String` allocation.
    pub algorithm: Arc<str>,
    pub p: usize,
    /// [`BlockPartition::fingerprint`] of the exact block layout.
    pub partition: u64,
    pub dtype: DType,
}

impl PlanKey {
    pub fn new(
        algorithm: impl Into<Arc<str>>,
        p: usize,
        part: &BlockPartition,
        dtype: DType,
    ) -> Self {
        Self { algorithm: algorithm.into(), p, partition: part.fingerprint(), dtype }
    }
}

/// Snapshot of a cache's counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PlanCacheStats {
    /// Lookups served from the cache.
    pub hits: u64,
    /// Lookups that had to build (including the never-cached collision
    /// fallback).
    pub misses: u64,
    /// Entries dropped to stay under the capacity bound.
    pub evictions: u64,
    /// Distinct plans currently held.
    pub entries: usize,
}

/// Default capacity bound ([`PlanCache::with_capacity`]): generous for
/// any realistic working set of collective geometries, while keeping a
/// long-lived serving engine fed arbitrary payload sizes from growing
/// its plan map without limit.
pub const DEFAULT_PLAN_CACHE_CAPACITY: usize = 1024;

/// One resident plan plus the logical timestamp of its last use — the
/// bookkeeping the LRU eviction policy runs on.
#[derive(Debug)]
struct Entry {
    plan: Arc<Plan>,
    /// Value of [`Inner::tick`] at the entry's last hit (or insert).
    stamp: u64,
}

/// The mutable half of the cache, under one lock: the plan map and the
/// monotone use counter that stamps entries.
#[derive(Debug, Default)]
struct Inner {
    map: HashMap<PlanKey, Entry>,
    tick: u64,
}

/// Thread-safe memo of built plans. Cheap to share: clone the `Arc` the
/// launcher/engine wraps it in.
///
/// Bounded with a **timestamp-counter LRU**: every lookup stamps its
/// entry with a monotone use counter, and inserting into a full cache
/// evicts the entry with the oldest stamp (evictions are counted in
/// [`PlanCacheStats`]). The stamp update is one store under the lock the
/// lookup already holds, so the *hit* path pays nothing extra — while
/// hot plans (the fusion tier's repeated batch shapes, a serving
/// engine's steady geometry mix) survive eviction pressure from a churn
/// of one-off shapes instead of being evicted arbitrarily. Eviction
/// itself is an O(capacity) min-stamp scan, deliberately: it runs only
/// on an insert at capacity, i.e. on a miss that just paid a full
/// schedule *build* (orders of magnitude more than scanning ≤1024
/// stamps), so linked-list LRU bookkeeping on every hit would cost more
/// than it saves.
#[derive(Debug)]
pub struct PlanCache {
    inner: Mutex<Inner>,
    capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    /// Audit every built plan regardless of build profile / knob — set by
    /// the engine's recovery path so survivor-set schedules are proved by
    /// the static verifier before their first post-reconfiguration use.
    force_audit: AtomicBool,
}

impl Default for PlanCache {
    fn default() -> Self {
        Self::with_capacity(DEFAULT_PLAN_CACHE_CAPACITY)
    }
}

impl PlanCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// A cache holding at most `capacity` plans (0 = unbounded).
    pub fn with_capacity(capacity: usize) -> Self {
        Self {
            inner: Mutex::new(Inner::default()),
            capacity,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            force_audit: AtomicBool::new(false),
        }
    }

    /// Audit every subsequently-built plan even when the build profile /
    /// `CCOLL_AUDIT_PLANS` would skip it. One-way in practice: recovery
    /// turns it on and leaves it on, so every survivor-set plan is proved
    /// before first use.
    pub fn set_force_audit(&self, on: bool) {
        self.force_audit.store(on, Ordering::Relaxed);
    }

    /// Look up `key`, building (and caching) the schedule on a miss.
    /// Returns the shared plan and whether this lookup was a hit.
    ///
    /// The build runs *outside* the lock, so concurrent ranks missing on
    /// the same key may build in parallel; the first insert wins and the
    /// losers adopt it (each still counts as a miss — they did the work).
    /// A fingerprint collision (stored partition ≠ requested) returns a
    /// fresh, **uncached** plan rather than ever serving a wrong schedule.
    pub fn get_or_build(
        &self,
        key: PlanKey,
        part: &BlockPartition,
        build: impl FnOnce() -> Schedule,
    ) -> (Arc<Plan>, bool) {
        let mut collision = false;
        {
            let mut inner = self.inner.lock().unwrap();
            inner.tick += 1;
            let tick = inner.tick;
            if let Some(entry) = inner.map.get_mut(&key) {
                if entry.plan.part == *part {
                    // LRU stamp: a hit marks the entry most-recently-used.
                    entry.stamp = tick;
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    return (entry.plan.clone(), true);
                }
                collision = true;
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let plan = Arc::new(Plan::new(build(), part.clone()));
        // Verified-by-construction: every plan that can enter the cache
        // passes the full static verifier while auditing is on (debug
        // builds always; release behind CCOLL_AUDIT_PLANS).
        if crate::analysis::audit_plans_enabled() || self.force_audit.load(Ordering::Relaxed) {
            if let Err(e) = crate::analysis::audit_plan(&key.algorithm, &plan.schedule, part) {
                panic!("plan audit failed [{}]: {e}", e.code());
            }
        }
        if collision {
            // Never cached: the slot is owned by the other layout.
            return (plan, false);
        }
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(existing) = inner.map.get_mut(&key) {
            // Raced with another builder; adopt the winner if its layout
            // matches (it does unless we also collided). Adoption is a
            // use, so it refreshes the entry's LRU stamp.
            if existing.plan.part == *part {
                existing.stamp = tick;
                return (existing.plan.clone(), false);
            }
            return (plan, false);
        }
        // Capacity bound: evict the least-recently-used resident entry
        // (oldest stamp) before inserting.
        if self.capacity > 0 && inner.map.len() >= self.capacity {
            if let Some(victim) =
                inner.map.iter().min_by_key(|(_, e)| e.stamp).map(|(k, _)| k.clone())
            {
                inner.map.remove(&victim);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        inner.map.insert(key, Entry { plan: plan.clone(), stamp: tick });
        (plan, false)
    }

    /// Counter + size snapshot.
    pub fn stats(&self) -> PlanCacheStats {
        PlanCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries: self.inner.lock().unwrap().map.len(),
        }
    }

    /// Drop every cached plan (counters are kept).
    pub fn clear(&self) {
        self.inner.lock().unwrap().map.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::generators::{allreduce_schedule, reduce_scatter_schedule};
    use crate::topology::skips::SkipScheme;

    fn build(p: usize, m: usize, allreduce: bool) -> (BlockPartition, Schedule) {
        let part = BlockPartition::regular(p, m);
        let skips = SkipScheme::HalvingUp.skips(p).unwrap();
        let sched =
            if allreduce { allreduce_schedule(p, &skips) } else { reduce_scatter_schedule(p, &skips) };
        (part, sched)
    }

    #[test]
    fn second_lookup_is_a_hit_and_shares_the_arc() {
        let cache = PlanCache::new();
        let (part, sched) = build(6, 60, true);
        let key = PlanKey::new("allreduce:halving-up", 6, &part, DType::F32);
        let (a, hit_a) = cache.get_or_build(key.clone(), &part, || sched.clone());
        let (b, hit_b) = cache.get_or_build(key, &part, || panic!("must not rebuild"));
        assert!(!hit_a && hit_b);
        assert!(Arc::ptr_eq(&a, &b), "hit must share the cached Arc");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
    }

    #[test]
    fn differing_partition_dtype_or_algorithm_miss() {
        let cache = PlanCache::new();
        let (part, sched) = build(5, 50, true);
        let (part2, _) = build(5, 55, true); // different layout
        let mk = |alg: &str, part: &BlockPartition, dt| PlanKey::new(alg, 5, part, dt);
        cache.get_or_build(mk("allreduce:halving-up", &part, DType::F32), &part, || sched.clone());
        // same algorithm, different partition → miss
        let (_, hit) = cache.get_or_build(
            mk("allreduce:halving-up", &part2, DType::F32),
            &part2,
            || sched.clone(),
        );
        assert!(!hit);
        // same partition, different dtype → miss
        let (_, hit) =
            cache.get_or_build(mk("allreduce:halving-up", &part, DType::I64), &part, || sched.clone());
        assert!(!hit);
        // same partition + dtype, different algorithm/scheme → miss
        let (_, hit) =
            cache.get_or_build(mk("allreduce:pow2", &part, DType::F32), &part, || sched.clone());
        assert!(!hit);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (0, 4, 4));
        // and each of those now hits
        let (_, hit) =
            cache.get_or_build(mk("allreduce:pow2", &part, DType::F32), &part, || unreachable!());
        assert!(hit);
    }

    #[test]
    fn fingerprint_collision_never_serves_a_wrong_plan() {
        // Forge a key whose fingerprint belongs to a *different* layout:
        // the cache must detect the mismatch and build fresh, uncached.
        let cache = PlanCache::new();
        let (part_a, sched_a) = build(4, 40, false);
        let (part_b, sched_b) = build(4, 44, false);
        let key_a = PlanKey::new("rs", 4, &part_a, DType::F32);
        cache.get_or_build(key_a.clone(), &part_a, || sched_a.clone());
        // Same key bits, but the caller's partition is B's layout.
        let (plan, hit) = cache.get_or_build(key_a, &part_b, || sched_b.clone());
        assert!(!hit);
        assert_eq!(plan.part, part_b, "must carry the requested layout");
        assert_eq!(cache.stats().entries, 1, "collision fallback is never cached");
    }

    #[test]
    fn capacity_bound_evicts_instead_of_growing() {
        let cache = PlanCache::with_capacity(4);
        for m in 0..10usize {
            let (part, sched) = build(3, 30 + m, true);
            cache.get_or_build(PlanKey::new("ar", 3, &part, DType::F32), &part, || sched.clone());
        }
        let s = cache.stats();
        assert!(s.entries <= 4, "{} entries exceed the capacity bound", s.entries);
        assert_eq!(s.evictions, 6, "10 distinct plans through a 4-slot cache");
        assert_eq!(s.misses, 10);
        // An evicted key simply rebuilds (a miss), never errors.
        let (part, sched) = build(3, 30, true);
        let (plan, _) = cache.get_or_build(PlanKey::new("ar", 3, &part, DType::F32), &part, || {
            sched.clone()
        });
        assert_eq!(plan.part, part);
    }

    #[test]
    fn eviction_order_is_least_recently_used() {
        // Capacity 3: insert A, B, C, then *touch* A (a hit refreshes its
        // LRU stamp). Inserting D must evict B — the least recently used
        // — never the hot A (what the old arbitrary-evict policy could
        // do to a fusion tier's hottest batch shape).
        let cache = PlanCache::with_capacity(3);
        let shapes: Vec<(BlockPartition, Schedule)> =
            (0..4).map(|i| build(3, 30 + i, true)).collect();
        let key = |i: usize| PlanKey::new("ar", 3, &shapes[i].0, DType::F32);
        for (i, (part, sched)) in shapes.iter().enumerate().take(3) {
            let (_, hit) = cache.get_or_build(key(i), part, || sched.clone());
            assert!(!hit, "insert {i}");
        }
        // Touch A (shape 0): now B (shape 1) is the oldest use.
        let (_, hit) = cache.get_or_build(key(0), &shapes[0].0, || unreachable!());
        assert!(hit, "A must hit");
        // Insert D (shape 3): evicts exactly one entry — B.
        cache.get_or_build(key(3), &shapes[3].0, || shapes[3].1.clone());
        assert_eq!(cache.stats().evictions, 1);
        assert_eq!(cache.stats().entries, 3);
        // Survivors first (hits don't mutate residency) — then prove B is
        // gone (its lookup is a miss, which re-inserts it and bumps the
        // eviction count once more).
        for i in [0usize, 2, 3] {
            let (_, hit) = cache.get_or_build(key(i), &shapes[i].0, || shapes[i].1.clone());
            assert!(hit, "shape {i}: LRU must have kept A/C/D");
        }
        let (_, hit) = cache.get_or_build(key(1), &shapes[1].0, || shapes[1].1.clone());
        assert!(!hit, "B must have been the eviction victim");
        assert_eq!(cache.stats().evictions, 2);
    }

    #[test]
    fn fingerprints_distinguish_layouts_with_equal_totals() {
        let a = BlockPartition::from_counts(&[2, 3, 5]);
        let b = BlockPartition::from_counts(&[3, 2, 5]);
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert_eq!(a.fingerprint(), BlockPartition::from_counts(&[2, 3, 5]).fingerprint());
    }
}
