//! Schedule IR — the common language of all collectives.
//!
//! Every algorithm in this library (Algorithm 1/2 and all baselines) is
//! expressed as a [`Schedule`]: per communication round, per rank, at most
//! one send and one receive of a *circular range of global blocks* plus the
//! action applied to received data. The same schedule object is:
//!
//!   * executed with real data over the thread transport
//!     (`collectives::exec`),
//!   * evaluated in the α-β-γ cost model (`sim::CostSimulator`), and
//!   * checked by structural property tests (`Schedule::assert_valid` and
//!     `rust/tests/prop_schedules.rs`).
//!
//! Block ranges use **global block ids** with the executor keeping buffers
//! in global layout; a range that wraps mod p resolves to at most two
//! contiguous memory slices (`BlockPartition::circular_ranges`). This is
//! the datatype-style zero-copy representation §3 of the paper alludes to —
//! no rotated copy of the input is ever materialized.

pub mod plan_cache;

pub use plan_cache::{Plan, PlanCache, PlanCacheStats, PlanKey};

use crate::datatypes::BlockPartition;

/// A circular range of `len` global blocks starting at block `start`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockRange {
    pub start: usize,
    pub len: usize,
}

impl BlockRange {
    pub fn new(start: usize, len: usize) -> Self {
        Self { start, len }
    }

    /// Normalize `start` into `0..p` (generators may produce `r + s`).
    pub fn normalized(self, p: usize) -> Self {
        Self { start: self.start % p, len: self.len }
    }

    /// Whether two circular block ranges share any block id (mod `p`).
    /// Both ranges must be normalized (`start < p`, `len ≤ p`). Two
    /// circular intervals overlap iff either start lies inside the other.
    pub fn overlaps(self, other: BlockRange, p: usize) -> bool {
        debug_assert!(self.start < p && self.len <= p);
        debug_assert!(other.start < p && other.len <= p);
        if self.len == 0 || other.len == 0 {
            return false;
        }
        ((other.start + p - self.start) % p) < self.len
            || ((self.start + p - other.start) % p) < other.len
    }
}

/// What the receiver does with an incoming payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecvAction {
    /// `R[range] ⊕= payload` — reduce-scatter phases.
    Combine,
    /// `R[range] ← payload` — allgather / broadcast phases.
    Store,
}

/// One rank's directed transfer in one round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Transfer {
    pub peer: usize,
    pub blocks: BlockRange,
}

/// One rank's receive in one round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Recv {
    pub peer: usize,
    pub blocks: BlockRange,
    pub action: RecvAction,
}

/// One rank's activity in one round (either side may be absent — e.g. tree
/// algorithms have one-directional rounds, folds have idle ranks).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RankStep {
    pub send: Option<Transfer>,
    pub recv: Option<Recv>,
}

impl RankStep {
    pub fn idle() -> Self {
        Self::default()
    }

    pub fn is_idle(&self) -> bool {
        self.send.is_none() && self.recv.is_none()
    }

    /// The zero-copy rendezvous precondition for this step: the send and
    /// recv block ranges are disjoint (one-sided steps trivially qualify),
    /// so a receiver may read the published send region while this rank
    /// writes only its recv range. This is THE predicate the executor
    /// uses for its per-round publish verdict and
    /// [`Schedule::rendezvous_safe`] aggregates — a memory-safety
    /// precondition, so both must always agree (hence one shared helper).
    pub fn rendezvous_safe(&self, p: usize) -> bool {
        match (&self.send, &self.recv) {
            (Some(send), Some(recv)) => {
                !send.blocks.normalized(p).overlaps(recv.blocks.normalized(p), p)
            }
            _ => true,
        }
    }
}

/// One synchronous communication round: `steps[r]` is rank r's activity.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Round {
    pub steps: Vec<RankStep>,
}

impl Round {
    pub fn idle(p: usize) -> Self {
        Self { steps: vec![RankStep::idle(); p] }
    }
}

/// A complete collective schedule for `p` ranks.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub p: usize,
    /// Human-readable algorithm name (for tables and error messages).
    pub name: String,
    pub rounds: Vec<Round>,
}

/// Why a schedule (or the skip sequence it was built from) is structurally
/// invalid. Library callers get these as `Result`s from
/// [`Schedule::validate`] and the `try_*` generator variants; the CLI and
/// [`Schedule::assert_valid`] still abort loudly by panicking with the
/// rendered message.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum ScheduleError {
    #[error("{name}: round {round} wrong arity: {got} steps for p={p}")]
    WrongArity { name: String, round: usize, got: usize, p: usize },
    #[error("{name}: r{rank} round {round} bad peer {peer} (p={p})")]
    BadPeer { name: String, rank: usize, round: usize, peer: usize, p: usize },
    #[error("{name}: r{rank} round {round} self-send")]
    SelfSend { name: String, rank: usize, round: usize },
    #[error("{name}: r{rank} round {round} bad send len {len} (p={p})")]
    BadSendLen { name: String, rank: usize, round: usize, len: usize, p: usize },
    #[error("{name}: r{rank} round {round} bad range start {start} (p={p})")]
    BadRangeStart { name: String, rank: usize, round: usize, start: usize, p: usize },
    #[error("{name}: r{rank} round {round} unmatched send to r{peer}")]
    UnmatchedSend { name: String, rank: usize, round: usize, peer: usize },
    #[error("{name}: round {round} recv peer mismatch at r{peer}: names r{got}, send came from r{rank}")]
    RecvPeerMismatch { name: String, round: usize, rank: usize, peer: usize, got: usize },
    #[error("{name}: round {round} {rank}\u{2192}{peer} block range mismatch (send {send:?}, recv {recv:?})")]
    RangeMismatch {
        name: String,
        round: usize,
        rank: usize,
        peer: usize,
        send: BlockRange,
        recv: BlockRange,
    },
    #[error("{name}: r{rank} round {round} unmatched recv from r{peer}")]
    UnmatchedRecv { name: String, rank: usize, round: usize, peer: usize },
    #[error("{name}: round {round} send peer mismatch at r{peer}: sends to r{got}, recv expects r{rank}")]
    SendPeerMismatch { name: String, round: usize, rank: usize, peer: usize, got: usize },
    /// The skip sequence a generator was handed is itself invalid.
    #[error(transparent)]
    Skips(#[from] crate::topology::skips::SkipError),
}

impl ScheduleError {
    /// Stable machine-readable diagnostic code (used by `ccoll audit`
    /// reports and the mutation-catch tests).
    pub fn code(&self) -> &'static str {
        match self {
            ScheduleError::WrongArity { .. } => "wrong-arity",
            ScheduleError::BadPeer { .. } => "bad-peer",
            ScheduleError::SelfSend { .. } => "self-send",
            ScheduleError::BadSendLen { .. } => "bad-send-len",
            ScheduleError::BadRangeStart { .. } => "bad-range-start",
            ScheduleError::UnmatchedSend { .. } => "unmatched-send",
            ScheduleError::RecvPeerMismatch { .. } => "recv-peer-mismatch",
            ScheduleError::RangeMismatch { .. } => "block-range-mismatch",
            ScheduleError::UnmatchedRecv { .. } => "unmatched-recv",
            ScheduleError::SendPeerMismatch { .. } => "send-peer-mismatch",
            ScheduleError::Skips(_) => "bad-skips",
        }
    }
}

/// Per-rank volume/round counters derived from a schedule — the quantities
/// Theorems 1 and 2 bound.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RankCounters {
    /// Rounds in which this rank sent or received anything.
    pub active_rounds: usize,
    pub blocks_sent: usize,
    pub blocks_recv: usize,
    pub elems_sent: usize,
    pub elems_recv: usize,
    /// Blocks combined with ⊕ (recv with `Combine`).
    pub blocks_combined: usize,
    pub elems_combined: usize,
}

impl Schedule {
    pub fn new(p: usize, name: impl Into<String>) -> Self {
        Self { p, name: name.into(), rounds: Vec::new() }
    }

    pub fn num_rounds(&self) -> usize {
        self.rounds.len()
    }

    /// Structural validation:
    ///  * step vectors sized `p`, peers in range, ranges in range;
    ///  * one-ported: ≤1 send and ≤1 recv per rank per round (by type);
    ///  * matching: every send `(r → t, B)` has at `t` a recv
    ///    `(from r, B)` over the *same global blocks*, and vice versa.
    ///
    /// Because every send must name the unique recv that accepts it (and
    /// vice versa), a `Ok(())` here is also a deadlock-freedom proof for
    /// the synchronous per-round execution model: no round can block on a
    /// message nobody sends.
    pub fn validate(&self) -> Result<(), ScheduleError> {
        let name = &self.name;
        for (k, round) in self.rounds.iter().enumerate() {
            if round.steps.len() != self.p {
                return Err(ScheduleError::WrongArity {
                    name: name.clone(),
                    round: k,
                    got: round.steps.len(),
                    p: self.p,
                });
            }
            for (r, step) in round.steps.iter().enumerate() {
                if let Some(send) = &step.send {
                    if send.peer >= self.p {
                        return Err(ScheduleError::BadPeer {
                            name: name.clone(),
                            rank: r,
                            round: k,
                            peer: send.peer,
                            p: self.p,
                        });
                    }
                    if send.peer == r {
                        return Err(ScheduleError::SelfSend { name: name.clone(), rank: r, round: k });
                    }
                    if send.blocks.len < 1 || send.blocks.len > self.p {
                        return Err(ScheduleError::BadSendLen {
                            name: name.clone(),
                            rank: r,
                            round: k,
                            len: send.blocks.len,
                            p: self.p,
                        });
                    }
                    if send.blocks.start >= self.p {
                        return Err(ScheduleError::BadRangeStart {
                            name: name.clone(),
                            rank: r,
                            round: k,
                            start: send.blocks.start,
                            p: self.p,
                        });
                    }
                    // matching recv at the peer
                    let peer_recv = round.steps[send.peer].recv.ok_or_else(|| {
                        ScheduleError::UnmatchedSend {
                            name: name.clone(),
                            rank: r,
                            round: k,
                            peer: send.peer,
                        }
                    })?;
                    if peer_recv.peer != r {
                        return Err(ScheduleError::RecvPeerMismatch {
                            name: name.clone(),
                            round: k,
                            rank: r,
                            peer: send.peer,
                            got: peer_recv.peer,
                        });
                    }
                    if peer_recv.blocks != send.blocks {
                        return Err(ScheduleError::RangeMismatch {
                            name: name.clone(),
                            round: k,
                            rank: r,
                            peer: send.peer,
                            send: send.blocks,
                            recv: peer_recv.blocks,
                        });
                    }
                }
                if let Some(recv) = &step.recv {
                    if recv.peer >= self.p {
                        return Err(ScheduleError::BadPeer {
                            name: name.clone(),
                            rank: r,
                            round: k,
                            peer: recv.peer,
                            p: self.p,
                        });
                    }
                    if recv.peer == r {
                        return Err(ScheduleError::SelfSend { name: name.clone(), rank: r, round: k });
                    }
                    let peer_send = round.steps[recv.peer].send.ok_or_else(|| {
                        ScheduleError::UnmatchedRecv {
                            name: name.clone(),
                            rank: r,
                            round: k,
                            peer: recv.peer,
                        }
                    })?;
                    if peer_send.peer != r {
                        return Err(ScheduleError::SendPeerMismatch {
                            name: name.clone(),
                            round: k,
                            rank: r,
                            peer: recv.peer,
                            got: peer_send.peer,
                        });
                    }
                }
            }
        }
        Ok(())
    }

    /// Panicking wrapper over [`Schedule::validate`] — tests and the CLI
    /// abort loudly; library callers should prefer `validate()`.
    pub fn assert_valid(&self) {
        if let Err(e) = self.validate() {
            panic!("{e}");
        }
    }

    /// Derive the per-rank counters under a block partition.
    pub fn counters(&self, part: &BlockPartition) -> Vec<RankCounters> {
        assert_eq!(part.p(), self.p);
        let mut out = vec![RankCounters::default(); self.p];
        for round in &self.rounds {
            for (r, step) in round.steps.iter().enumerate() {
                if step.is_idle() {
                    continue;
                }
                out[r].active_rounds += 1;
                if let Some(send) = &step.send {
                    let b = send.blocks.normalized(self.p);
                    out[r].blocks_sent += b.len;
                    out[r].elems_sent += part.circular_elems(b.start, b.len);
                }
                if let Some(recv) = &step.recv {
                    let b = recv.blocks.normalized(self.p);
                    out[r].blocks_recv += b.len;
                    let elems = part.circular_elems(b.start, b.len);
                    out[r].elems_recv += elems;
                    if recv.action == RecvAction::Combine {
                        out[r].blocks_combined += b.len;
                        out[r].elems_combined += elems;
                    }
                }
            }
        }
        out
    }

    /// Rendezvous precondition (the zero-copy transport tier): in every
    /// round, every rank's send and recv block ranges are disjoint, so a
    /// receiver may read the sender's working vector *while the sender
    /// combines into its own recv range* without racing. Every schedule
    /// this library generates satisfies it except full-vector
    /// recursive-doubling allreduce (send range == recv range == all
    /// blocks), which the executor runs on the pooled tier instead — the
    /// check is per (rank, round), so mixed schedules degrade only the
    /// overlapping steps.
    pub fn rendezvous_safe(&self) -> bool {
        self.rounds
            .iter()
            .all(|round| round.steps.iter().all(|step| step.rendezvous_safe(self.p)))
    }

    /// Max blocks in any single message — the §3 "no sequence longer than
    /// ⌈p/2⌉" property for the halving-up scheme.
    pub fn max_message_blocks(&self) -> usize {
        self.rounds
            .iter()
            .flat_map(|r| r.steps.iter())
            .filter_map(|s| s.send.map(|t| t.blocks.len))
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_valid() -> Schedule {
        // p=2, one round: 0 and 1 swap block ranges of themselves.
        let mut s = Schedule::new(2, "tiny");
        let step0 = RankStep {
            send: Some(Transfer { peer: 1, blocks: BlockRange::new(1, 1) }),
            recv: Some(Recv { peer: 1, blocks: BlockRange::new(0, 1), action: RecvAction::Combine }),
        };
        let step1 = RankStep {
            send: Some(Transfer { peer: 0, blocks: BlockRange::new(0, 1) }),
            recv: Some(Recv { peer: 0, blocks: BlockRange::new(1, 1), action: RecvAction::Combine }),
        };
        s.rounds.push(Round { steps: vec![step0, step1] });
        s
    }

    #[test]
    fn valid_schedule_passes() {
        tiny_valid().assert_valid();
    }

    #[test]
    #[should_panic(expected = "unmatched send")]
    fn unmatched_send_caught() {
        let mut s = tiny_valid();
        s.rounds[0].steps[1].recv = None;
        s.assert_valid();
    }

    #[test]
    #[should_panic(expected = "block range mismatch")]
    fn range_mismatch_caught() {
        let mut s = tiny_valid();
        s.rounds[0].steps[1].recv.as_mut().unwrap().blocks = BlockRange::new(0, 2);
        s.assert_valid();
    }

    #[test]
    fn validate_returns_typed_errors() {
        assert!(tiny_valid().validate().is_ok());

        let mut s = tiny_valid();
        s.rounds[0].steps[1].recv = None;
        let e = s.validate().unwrap_err();
        assert_eq!(e.code(), "unmatched-send");
        assert!(e.to_string().contains("unmatched send"));

        let mut s = tiny_valid();
        s.rounds[0].steps[1].recv.as_mut().unwrap().blocks = BlockRange::new(0, 2);
        let e = s.validate().unwrap_err();
        assert_eq!(e.code(), "block-range-mismatch");
        assert!(e.to_string().contains("block range mismatch"));

        // Rank 0's send reaches rank 1 first, whose recv now names the
        // wrong origin — the send-side matching check fires.
        let mut s = tiny_valid();
        s.rounds[0].steps[1].recv.as_mut().unwrap().peer = 1;
        assert_eq!(s.validate().unwrap_err().code(), "recv-peer-mismatch");
    }

    #[test]
    fn counters_count() {
        let part = BlockPartition::uniform(2, 4);
        let c = tiny_valid().counters(&part);
        assert_eq!(c[0].blocks_sent, 1);
        assert_eq!(c[0].elems_sent, 4);
        assert_eq!(c[0].elems_combined, 4);
        assert_eq!(c[0].active_rounds, 1);
    }

    #[test]
    fn normalization_wraps() {
        assert_eq!(BlockRange::new(7, 2).normalized(5), BlockRange::new(2, 2));
    }

    #[test]
    fn overlap_detection_matches_block_sets() {
        // Brute force: compare against explicit block-set intersection.
        let p = 7;
        for s1 in 0..p {
            for l1 in 0..=p {
                for s2 in 0..p {
                    for l2 in 0..=p {
                        let a = BlockRange::new(s1, l1);
                        let b = BlockRange::new(s2, l2);
                        let set =
                            |r: BlockRange| (0..r.len).map(|i| (r.start + i) % p).collect::<std::collections::HashSet<_>>();
                        let want = !set(a).is_disjoint(&set(b));
                        assert_eq!(a.overlaps(b, p), want, "{a:?} vs {b:?}");
                        assert_eq!(b.overlaps(a, p), want, "symmetry {a:?} vs {b:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn rendezvous_safety_classifies_schedules() {
        // The tiny swap schedule exchanges disjoint blocks — safe.
        assert!(tiny_valid().rendezvous_safe());
        // A full-vector exchange (send range == recv range) is not.
        let mut s = Schedule::new(2, "full-swap");
        let all = BlockRange::new(0, 2);
        let step0 = RankStep {
            send: Some(Transfer { peer: 1, blocks: all }),
            recv: Some(Recv { peer: 1, blocks: all, action: RecvAction::Combine }),
        };
        let step1 = RankStep {
            send: Some(Transfer { peer: 0, blocks: all }),
            recv: Some(Recv { peer: 0, blocks: all, action: RecvAction::Combine }),
        };
        s.rounds.push(Round { steps: vec![step0, step1] });
        s.assert_valid();
        assert!(!s.rendezvous_safe());
        // One-sided rounds are trivially safe.
        let mut t = Schedule::new(2, "one-sided");
        t.rounds.push(Round {
            steps: vec![
                RankStep { send: Some(Transfer { peer: 1, blocks: all }), recv: None },
                RankStep {
                    send: None,
                    recv: Some(Recv { peer: 0, blocks: all, action: RecvAction::Store }),
                },
            ],
        });
        assert!(t.rendezvous_safe());
    }
}
