//! Perf — hot-path microbenchmarks and ablations (EXPERIMENTS.md §Perf).
//!
//! Measured here:
//!   1. native ⊕ throughput per operator vs the single-core streaming
//!      roofline (a plain slice copy),
//!   2. the §3 ablation: one bulk combine over a run of blocks vs p
//!      per-block combines (why the schedule keeps runs consecutive),
//!   3. message pack (gather of ≤2 slices) throughput,
//!   4. PJRT combine throughput per bucket (kernel dispatch amortization),
//!   5. end-to-end threaded allreduce wall-clock vs DES prediction
//!      (correlation sanity for using DES in F1/F2).

use circulant_collectives::bench_harness::{bench_header, fast_mode, time_adaptive};
use circulant_collectives::collectives::{allreduce_schedule, run_schedule_threads};
use circulant_collectives::datatypes::BlockPartition;
use circulant_collectives::ops::{MaxOp, MinOp, ProdOp, ReduceOp, SumOp};
use circulant_collectives::runtime::{default_artifact_dir, Engine};
use circulant_collectives::sim::{simulate, CostModel};
use circulant_collectives::topology::skips::SkipScheme;
use circulant_collectives::util::rng::SplitMix64;
use circulant_collectives::util::stats::pearson;
use circulant_collectives::util::table::{fmt_si, Table};
use std::sync::Arc;

fn gbps(elems: usize, seconds: f64) -> f64 {
    // combine reads 2 vectors and writes 1 → 12 bytes per element
    12.0 * elems as f64 / seconds / 1e9
}

fn main() {
    bench_header("Perf", "hot-path throughput & ablations");
    let n = 1 << 20;
    let mut rng = SplitMix64::new(9);
    let a0 = rng.normal_vec(n);
    let b = rng.normal_vec(n);
    let reps = if fast_mode() { 3 } else { 7 };

    // 1. native ops vs streaming roofline ------------------------------
    let mut t = Table::new("native ⊕ throughput (1 Mi f32)", &["op", "median time", "GB/s", "of copy roofline"]);
    let mut a = a0.clone();
    let copy = time_adaptive(0.05, reps, || {
        a.copy_from_slice(&b);
        std::hint::black_box(&a);
    });
    let copy_gbps = 8.0 * n as f64 / copy.median / 1e9; // read+write
    t.row(&["copy (roofline)".into(), format!("{}s", fmt_si(copy.median)), format!("{copy_gbps:.1}"), "100%".into()]);
    let ops: Vec<(&str, Box<dyn ReduceOp>)> = vec![
        ("sum", Box::new(SumOp)),
        ("prod", Box::new(ProdOp)),
        ("min", Box::new(MinOp)),
        ("max", Box::new(MaxOp)),
    ];
    // prod note: repeated in-place multiply by N(0,1) data underflows to
    // denormals within a few hundred batched iterations, stalling the FPU
    // (§Perf iteration 2). Use unit-magnitude ±1 factors so magnitudes are
    // invariant under arbitrarily many repetitions — measures the op, not
    // the drift.
    let b_unit: Vec<f32> = b.iter().map(|x| if *x >= 0.0 { 1.0f32 } else { -1.0 }).collect();
    let mut sum_ratio = 0.0;
    for (name, op) in &ops {
        let other = if *name == "prod" { &b_unit } else { &b };
        let mut acc = a0.clone();
        let s = time_adaptive(0.05, reps, || {
            op.combine(&mut acc, other);
            std::hint::black_box(&acc);
        });
        let g = gbps(n, s.median);
        let ratio = g / (copy_gbps * 1.5); // combine moves 12B vs copy's 8B per elem
        if *name == "sum" {
            sum_ratio = ratio;
        }
        t.row(&[name.to_string(), format!("{}s", fmt_si(s.median)), format!("{g:.1}"), format!("{:.0}%", 100.0 * ratio)]);
    }
    t.print();

    // 2. bulk vs per-block combine (§3 ablation) ------------------------
    // The §3 point is per-call overhead on *small* blocks: a round's run of
    // consecutive blocks is reduced with ONE bulk call instead of one call
    // per block. Sweep block granularity at fixed total volume.
    println!("bulk combine vs per-block combines (total 1 Mi f32):");
    for p_blocks in [64usize, 1024, 16384, 131072] {
        let blk = n / p_blocks;
        let mut acc = a0.clone();
        let bulk = time_adaptive(0.05, reps, || {
            SumOp.combine(&mut acc, &b);
            std::hint::black_box(&acc);
        });
        let mut acc2 = a0.clone();
        let per_block = time_adaptive(0.05, reps, || {
            for i in 0..p_blocks {
                SumOp.combine(&mut acc2[i * blk..(i + 1) * blk], &b[i * blk..(i + 1) * blk]);
            }
            std::hint::black_box(&acc2);
        });
        println!(
            "  {p_blocks:>6} blocks of {blk:>5}: bulk {}s vs per-block {}s ({:.2}×)",
            fmt_si(bulk.median),
            fmt_si(per_block.median),
            per_block.median / bulk.median
        );
    }
    println!();

    // 3. pack throughput -------------------------------------------------
    let part = BlockPartition::regular(64, n);
    let (ra, rb) = part.circular_ranges(40, 40); // wraps
    let mut scratch: Vec<f32> = Vec::with_capacity(n);
    let pack = time_adaptive(0.05, reps, || {
        scratch.clear();
        scratch.extend_from_slice(&a0[ra.clone()]);
        if let Some(rbx) = rb.clone() {
            scratch.extend_from_slice(&a0[rbx]);
        }
        std::hint::black_box(&scratch);
    });
    let packed = ra.len() + rb.clone().map_or(0, |r| r.len());
    println!(
        "message pack (gather 2 slices, {} elems): {}s = {:.1} GB/s\n",
        packed,
        fmt_si(pack.median),
        8.0 * packed as f64 / pack.median / 1e9
    );

    // 4. PJRT combine per bucket -----------------------------------------
    match Engine::load(default_artifact_dir()) {
        Ok(engine) => {
            let mut t = Table::new(
                "PJRT combine (AOT Pallas kernel) per bucket",
                &["bucket", "median time", "Melem/s", "vs native sum"],
            );
            let buckets = engine.manifest.buckets.clone();
            // native reference at the largest bucket
            let nb = *buckets.last().unwrap();
            let mut accn = a0[..nb].to_vec();
            let nat = time_adaptive(0.05, reps, || {
                SumOp.combine(&mut accn, &b[..nb]);
                std::hint::black_box(&accn);
            });
            for &nbkt in &buckets {
                let mut acc = a0[..nbkt].to_vec();
                let s = time_adaptive(0.05, reps, || {
                    engine.combine_bucket_exact("sum", &mut acc, &b[..nbkt]).unwrap();
                    std::hint::black_box(&acc);
                });
                let native_equiv = nat.median * nbkt as f64 / nb as f64;
                t.row(&[
                    nbkt.to_string(),
                    format!("{}s", fmt_si(s.median)),
                    fmt_si(nbkt as f64 / s.median / 1e6),
                    format!("{:.1}× slower", s.median / native_equiv),
                ]);
            }
            t.print();
            // Large-request policy: combine_into chunks at the sweet spot
            // (CCOLL_PJRT_CHUNK to override; see §Perf iteration 1).
            let big = 300_000usize;
            let mut acc = a0[..big.min(n)].to_vec();
            let bb = b[..big.min(n)].to_vec();
            let s = time_adaptive(0.05, reps, || {
                engine.combine_into("sum", &mut acc, &bb, 0.0).unwrap();
                std::hint::black_box(&acc);
            });
            println!(
                "large request ({big} elems) via chunking policy: {}s = {} elem/s\n",
                fmt_si(s.median),
                fmt_si(big as f64 / s.median)
            );
            println!("(interpret-mode grid loops make big buckets slower per element, so");
            println!(" combine_into chunks at the measured sweet-spot bucket — §Perf log)\n");
        }
        Err(e) => println!("PJRT section skipped: {e}\n"),
    }

    // 5. threaded wall-clock vs CALIBRATED DES ---------------------------
    let ps = if fast_mode() { vec![2usize, 4] } else { vec![2usize, 4, 8, 12, 16] };
    let m = 1 << 18;
    let model = circulant_collectives::sim::calibrate::calibrate_transport(&SumOp, 2);
    println!(
        "calibrated transport model: α={:.2e}s β={:.2e}s/elem γ={:.2e}s/elem",
        model.alpha, model.beta, model.gamma
    );
    let mut wall = Vec::new();
    let mut des = Vec::new();
    // On a single physical core, p rank threads serialize: expect
    // wall ≈ DES · p (the DES assumes each rank has its own processor).
    let mut t =
        Table::new("threaded allreduce vs DES", &["p", "wall", "DES", "ratio", "ratio/p (1-core)"]);
    for &p in &ps {
        let part = BlockPartition::regular(p, m);
        let skips = SkipScheme::HalvingUp.skips(p).unwrap();
        let sched = allreduce_schedule(p, &skips);
        let mut rng = SplitMix64::new(p as u64);
        let inputs: Vec<Vec<f32>> = (0..p).map(|_| rng.normal_vec(m)).collect();
        let mut best = f64::INFINITY;
        for _ in 0..reps.max(3) {
            let t0 = std::time::Instant::now();
            let _ = run_schedule_threads(&sched, &part, Arc::new(SumOp), inputs.clone());
            best = best.min(t0.elapsed().as_secs_f64());
        }
        let sim = simulate(&sched, &part, &model).total;
        wall.push(best);
        des.push(sim);
        t.row(&[
            p.to_string(),
            format!("{}s", fmt_si(best)),
            format!("{}s", fmt_si(sim)),
            format!("{:.2}", best / sim),
            format!("{:.2}", best / (sim * p as f64)),
        ]);
    }
    t.print();
    if wall.len() > 2 {
        let r = pearson(&wall, &des);
        println!("wall vs DES Pearson r = {r:.3} (DES is a faithful relative predictor)");
    }

    // quality gates recorded in EXPERIMENTS.md §Perf
    assert!(sum_ratio > 0.5, "native sum below 50% of streaming roofline: {sum_ratio:.2}");
}
