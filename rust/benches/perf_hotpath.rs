//! Perf — hot-path microbenchmarks and ablations (EXPERIMENTS.md §Perf).
//!
//! Measured here:
//!   1. native ⊕ throughput per operator vs the single-core streaming
//!      roofline (a plain slice copy),
//!   2. the §3 ablation: one bulk combine over a run of blocks vs p
//!      per-block combines (why the schedule keeps runs consecutive),
//!   3. message pack (gather of ≤2 slices) throughput, plus the
//!      allocation-count ablation: pooled borrow-pack transport vs a
//!      fresh `Vec` per round (zero steady-state payload allocations),
//!      and the copy-volume/throughput ablation of the three transport
//!      tiers: rendezvous (zero-copy) vs pooled (single-copy) vs the
//!      pre-pool fresh-`Vec` executor on a large-m allreduce,
//!   4. PJRT combine throughput per bucket (kernel dispatch amortization),
//!   5. end-to-end threaded allreduce wall-clock vs DES prediction
//!      (correlation sanity for using DES in F1/F2).
//!
//! Results are persisted to `BENCH_hotpath.json` (see
//! `bench_harness::BenchReport`) so the perf trajectory is tracked across
//! PRs.

use circulant_collectives::bench_harness::{bench_header, fast_mode, time_adaptive, BenchReport};
use circulant_collectives::collectives::{allreduce_schedule, run_schedule_threads};
use circulant_collectives::datatypes::BlockPartition;
use circulant_collectives::ops::{MaxOp, MinOp, ProdOp, ReduceOp, SumOp};
use circulant_collectives::runtime::{default_artifact_dir, Engine};
use circulant_collectives::sim::{simulate, CostModel};
use circulant_collectives::topology::skips::SkipScheme;
use circulant_collectives::transport::Counters;
use circulant_collectives::util::rng::SplitMix64;
use circulant_collectives::util::stats::pearson;
use circulant_collectives::util::table::{fmt_si, Table};
use std::sync::Arc;

// Counting allocator for the section-3 allocation ablation: every
// alloc/realloc anywhere in the process bumps the counter (dealloc is
// free), so per-round deltas compare the pooled executor against the
// fresh-Vec-per-round variant on equal terms.
mod alloc_count {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::sync::atomic::{AtomicU64, Ordering};

    pub static ALLOCS: AtomicU64 = AtomicU64::new(0);

    pub struct Counting;

    unsafe impl GlobalAlloc for Counting {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            System.alloc(layout)
        }
        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            System.dealloc(ptr, layout)
        }
        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            System.realloc(ptr, layout, new_size)
        }
    }

    pub fn now() -> u64 {
        ALLOCS.load(Ordering::Relaxed)
    }
}

#[global_allocator]
static COUNTING_ALLOC: alloc_count::Counting = alloc_count::Counting;

fn gbps(elems: usize, seconds: f64) -> f64 {
    // combine reads 2 vectors and writes 1 → 12 bytes per element
    12.0 * elems as f64 / seconds / 1e9
}

/// The pre-pool executor, kept verbatim as the ablation baseline: packs
/// every outgoing payload into a brand-new `Vec` and drops every received
/// one (ownership-transfer `sendrecv_owned`, no recycling).
fn execute_rank_fresh_vec(
    ep: &mut circulant_collectives::transport::Endpoint,
    schedule: &circulant_collectives::schedule::Schedule,
    part: &BlockPartition,
    op: &dyn ReduceOp,
    buf: &mut [f32],
    round_base: u64,
) -> u64 {
    use circulant_collectives::schedule::RecvAction;
    let p = schedule.p;
    let r = ep.rank;
    for (k, round) in schedule.rounds.iter().enumerate() {
        let step = &round.steps[r];
        if step.is_idle() {
            continue;
        }
        let tag = round_base + k as u64;
        let send = step.send.as_ref().map(|t| {
            let b = t.blocks.normalized(p);
            let (a, rest) = part.circular_ranges(b.start, b.len);
            let mut payload = Vec::with_capacity(part.circular_elems(b.start, b.len));
            payload.extend_from_slice(&buf[a]);
            if let Some(rest) = rest {
                payload.extend_from_slice(&buf[rest]);
            }
            (t.peer, payload)
        });
        let recv_from = step.recv.as_ref().map(|rv| rv.peer);
        let payload = ep.sendrecv_owned(send, recv_from, tag).unwrap();
        if let (Some(rv), Some(payload)) = (step.recv.as_ref(), payload) {
            let b = rv.blocks.normalized(p);
            let (a, rest) = part.circular_ranges(b.start, b.len);
            let split = a.len();
            match rv.action {
                RecvAction::Combine => {
                    op.combine(&mut buf[a], &payload[..split]);
                    if let Some(rest) = rest {
                        op.combine(&mut buf[rest], &payload[split..]);
                    }
                }
                RecvAction::Store => {
                    // mirror the real executor's copy accounting
                    ep.counters.bytes_copied += 4 * payload.len() as u64;
                    buf[a].copy_from_slice(&payload[..split]);
                    if let Some(rest) = rest {
                        buf[rest].copy_from_slice(&payload[split..]);
                    }
                }
            }
            // payload dropped here: freed, never recycled.
        }
    }
    round_base + schedule.rounds.len() as u64
}

/// Transport tier under ablation in §3c.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Tier {
    /// Zero-copy descriptor publish (the default executor hot path).
    Rendezvous,
    /// Pooled gather (the PR-1 executor).
    Pooled,
    /// Fresh `Vec` per round (the pre-pool executor).
    FreshVec,
}

impl Tier {
    fn name(self) -> &'static str {
        match self {
            Tier::Rendezvous => "rendezvous",
            Tier::Pooled => "pooled",
            Tier::FreshVec => "fresh-Vec",
        }
    }
}

/// Run `iters` back-to-back allreduces on one fresh thread network with
/// the given transport tier; returns (wall seconds, per-rank counters).
fn timed_allreduce(
    sched: &Arc<circulant_collectives::schedule::Schedule>,
    part: &Arc<BlockPartition>,
    m: usize,
    tier: Tier,
    iters: u64,
) -> (f64, Vec<Counters>) {
    use circulant_collectives::transport::run_ranks_inputs;
    let p = sched.p;
    let sched = sched.clone();
    let part = part.clone();
    let inputs: Vec<Vec<f32>> = (0..p).map(|r| vec![r as f32 + 1.0; m]).collect();
    let t0 = std::time::Instant::now();
    let counters = run_ranks_inputs(inputs, move |_rank, ep, mut buf: Vec<f32>| {
        ep.rendezvous = tier == Tier::Rendezvous;
        ep.rendezvous_min_elems = 0;
        let mut tag = 0u64;
        for _ in 0..iters {
            tag = match tier {
                Tier::FreshVec => execute_rank_fresh_vec(ep, &sched, &part, &SumOp, &mut buf, tag),
                _ => circulant_collectives::collectives::execute_rank(
                    ep, &sched, &part, &SumOp, &mut buf, tag,
                )
                .unwrap(),
            };
        }
        ep.counters.clone()
    });
    (t0.elapsed().as_secs_f64(), counters)
}

fn main() {
    bench_header("Perf", "hot-path throughput & ablations");
    let mut report = BenchReport::new("hotpath");
    let n = 1 << 20;
    let mut rng = SplitMix64::new(9);
    let a0 = rng.normal_vec(n);
    let b = rng.normal_vec(n);
    let reps = if fast_mode() { 3 } else { 7 };

    // 1. native ops vs streaming roofline ------------------------------
    let mut t = Table::new("native ⊕ throughput (1 Mi f32)", &["op", "median time", "GB/s", "of copy roofline"]);
    let mut a = a0.clone();
    let copy = time_adaptive(0.05, reps, || {
        a.copy_from_slice(&b);
        std::hint::black_box(&a);
    });
    let copy_gbps = 8.0 * n as f64 / copy.median / 1e9; // read+write
    t.row(&["copy (roofline)".into(), format!("{}s", fmt_si(copy.median)), format!("{copy_gbps:.1}"), "100%".into()]);
    let ops: Vec<(&str, Box<dyn ReduceOp>)> = vec![
        ("sum", Box::new(SumOp)),
        ("prod", Box::new(ProdOp)),
        ("min", Box::new(MinOp)),
        ("max", Box::new(MaxOp)),
    ];
    // prod note: repeated in-place multiply by N(0,1) data underflows to
    // denormals within a few hundred batched iterations, stalling the FPU
    // (§Perf iteration 2). Use unit-magnitude ±1 factors so magnitudes are
    // invariant under arbitrarily many repetitions — measures the op, not
    // the drift.
    let b_unit: Vec<f32> = b.iter().map(|x| if *x >= 0.0 { 1.0f32 } else { -1.0 }).collect();
    let mut sum_ratio = 0.0;
    for (name, op) in &ops {
        let other = if *name == "prod" { &b_unit } else { &b };
        let mut acc = a0.clone();
        let s = time_adaptive(0.05, reps, || {
            op.combine(&mut acc, other);
            std::hint::black_box(&acc);
        });
        let g = gbps(n, s.median);
        let ratio = g / (copy_gbps * 1.5); // combine moves 12B vs copy's 8B per elem
        if *name == "sum" {
            sum_ratio = ratio;
        }
        report.num(&format!("native_{name}_gbps"), g);
        t.row(&[name.to_string(), format!("{}s", fmt_si(s.median)), format!("{g:.1}"), format!("{:.0}%", 100.0 * ratio)]);
    }
    report.num("copy_roofline_gbps", copy_gbps);
    t.print();

    // 2. bulk vs per-block combine (§3 ablation) ------------------------
    // The §3 point is per-call overhead on *small* blocks: a round's run of
    // consecutive blocks is reduced with ONE bulk call instead of one call
    // per block. Sweep block granularity at fixed total volume.
    println!("bulk combine vs per-block combines (total 1 Mi f32):");
    for p_blocks in [64usize, 1024, 16384, 131072] {
        let blk = n / p_blocks;
        let mut acc = a0.clone();
        let bulk = time_adaptive(0.05, reps, || {
            SumOp.combine(&mut acc, &b);
            std::hint::black_box(&acc);
        });
        let mut acc2 = a0.clone();
        let per_block = time_adaptive(0.05, reps, || {
            for i in 0..p_blocks {
                SumOp.combine(&mut acc2[i * blk..(i + 1) * blk], &b[i * blk..(i + 1) * blk]);
            }
            std::hint::black_box(&acc2);
        });
        println!(
            "  {p_blocks:>6} blocks of {blk:>5}: bulk {}s vs per-block {}s ({:.2}×)",
            fmt_si(bulk.median),
            fmt_si(per_block.median),
            per_block.median / bulk.median
        );
    }
    println!();

    // 3. pack throughput -------------------------------------------------
    let part = BlockPartition::regular(64, n);
    let (ra, rb) = part.circular_ranges(40, 40); // wraps
    let mut scratch: Vec<f32> = Vec::with_capacity(n);
    let pack = time_adaptive(0.05, reps, || {
        scratch.clear();
        scratch.extend_from_slice(&a0[ra.clone()]);
        if let Some(rbx) = rb.clone() {
            scratch.extend_from_slice(&a0[rbx]);
        }
        std::hint::black_box(&scratch);
    });
    let packed = ra.len() + rb.clone().map_or(0, |r| r.len());
    println!(
        "message pack (gather 2 slices, {} elems): {}s = {:.1} GB/s\n",
        packed,
        fmt_si(pack.median),
        8.0 * packed as f64 / pack.median / 1e9
    );

    // 3b. allocation ablation: pooled borrow-pack vs fresh Vec per round -
    // Back-to-back threaded allreduces on one network; the counting
    // allocator reports process-wide allocations per schedule round, and
    // the endpoint counters report exact payload-buffer pool hits/misses.
    {
        use circulant_collectives::transport::run_ranks;
        let p = 4usize;
        let mab = 1 << 14;
        let part = Arc::new(BlockPartition::regular(p, mab));
        let skips = SkipScheme::HalvingUp.skips(p).unwrap();
        let sched = Arc::new(allreduce_schedule(p, &skips));
        let rounds_per_iter = sched.rounds.len() as u64;
        let (warm, total) = (20u64, 120u64);
        let measured_rounds = (total - warm) * rounds_per_iter;

        // pooled (the real executor)
        let sched2 = sched.clone();
        let part2 = part.clone();
        let a0_allocs = alloc_count::now();
        let pooled = run_ranks(p, move |rank, ep| {
            let mut buf = vec![rank as f32 + 1.0; mab];
            let mut tag = 0u64;
            for _ in 0..warm {
                tag = circulant_collectives::collectives::execute_rank(
                    ep, &sched2, &part2, &SumOp, &mut buf, tag,
                )
                .unwrap();
            }
            let warm_misses = ep.counters.pool_misses;
            for _ in warm..total {
                tag = circulant_collectives::collectives::execute_rank(
                    ep, &sched2, &part2, &SumOp, &mut buf, tag,
                )
                .unwrap();
            }
            (warm_misses, ep.counters.clone())
        });
        let pooled_total_allocs = alloc_count::now() - a0_allocs;

        // fresh-Vec baseline (the pre-pool executor)
        let sched3 = sched.clone();
        let part3 = part.clone();
        let f0 = alloc_count::now();
        let _fresh = run_ranks(p, move |rank, ep| {
            let mut buf = vec![rank as f32 + 1.0; mab];
            let mut tag = 0u64;
            for _ in 0..total {
                tag = execute_rank_fresh_vec(ep, &sched3, &part3, &SumOp, &mut buf, tag);
            }
        });
        let fresh_total_allocs = alloc_count::now() - f0;

        let steady_misses: u64 = pooled.iter().map(|(w, c)| c.pool_misses - w).sum();
        let hits: u64 = pooled.iter().map(|(_, c)| c.pool_hits).sum();
        let misses: u64 = pooled.iter().map(|(_, c)| c.pool_misses).sum();
        let hit_rate = 100.0 * hits as f64 / (hits + misses).max(1) as f64;
        println!("allocation ablation (threaded allreduce p={p}, m={mab}, {} steady rounds/rank):", measured_rounds);
        println!(
            "  pooled:    {} total allocs, payload pool {} hits / {} misses ({hit_rate:.1}% hit rate, {} misses after warm-up)",
            pooled_total_allocs, hits, misses, steady_misses
        );
        println!(
            "  fresh-Vec: {} total allocs ({:.1}× the pooled path)",
            fresh_total_allocs,
            fresh_total_allocs as f64 / pooled_total_allocs.max(1) as f64
        );
        let steady_hit_rate = 100.0
            * (1.0 - steady_misses as f64 / (measured_rounds * p as u64) as f64);
        println!(
            "  steady-state payload allocations per round: {:.4} (pooled), post-warm-up hit rate {steady_hit_rate:.2}%\n",
            steady_misses as f64 / measured_rounds as f64
        );
        // Quality gate: steady-state misses must not scale with rounds
        // (a per-round allocation regression would show ~1 per round; a
        // handful is the bounded release/acquire race, see transport docs).
        assert!(
            steady_misses <= measured_rounds / 50,
            "pooled transport allocated payloads after warm-up: {steady_misses} misses over {measured_rounds} rounds/rank"
        );
        report.num("alloc_pooled_total", pooled_total_allocs as f64);
        report.num("alloc_fresh_vec_total", fresh_total_allocs as f64);
        report.num("alloc_pooled_steady_misses", steady_misses as f64);
        report.num("alloc_pool_hit_rate_pct", hit_rate);
    }

    // 3c. copy-volume & throughput ablation: the three transport tiers ----
    // Large-m allreduce (working vectors ≥ 1 MiB) on one network per tier:
    // rendezvous publishes descriptors and combines straight from the
    // sender's memory (zero gather copies), pooled gathers every payload
    // into a loaned buffer (PR-1), fresh-Vec additionally allocates it
    // (pre-pool). `bytes_copied` counts gather + Store-scatter bytes.
    {
        let p = 4usize;
        let m: usize = if fast_mode() { 1 << 18 } else { 1 << 20 }; // 1 MiB / 4 MiB vectors
        let iters: u64 = if fast_mode() { 8 } else { 24 };
        let runs = if fast_mode() { 2 } else { 3 };
        let part = Arc::new(BlockPartition::regular(p, m));
        let skips = SkipScheme::HalvingUp.skips(p).unwrap();
        let sched = Arc::new(allreduce_schedule(p, &skips));
        assert!(sched.rendezvous_safe(), "circulant allreduce must be rendezvous-safe");

        let mut t = Table::new(
            &format!("transport-tier ablation (allreduce p={p}, m={m} f32, {iters} iters)"),
            &["tier", "wall", "Melem/s", "MB copied", "rdv hits", "pool acquires"],
        );
        let mut results = Vec::new();
        for tier in [Tier::Rendezvous, Tier::Pooled, Tier::FreshVec] {
            let mut best = f64::INFINITY;
            let mut counters: Vec<Counters> = Vec::new();
            for _ in 0..runs {
                let (secs, cs) = timed_allreduce(&sched, &part, m, tier, iters);
                if secs < best {
                    best = secs;
                }
                counters = cs;
            }
            let bytes: u64 = counters.iter().map(|c| c.bytes_copied).sum();
            let rdv: u64 = counters.iter().map(|c| c.rendezvous_hits).sum();
            let acq: u64 = counters.iter().map(|c| c.pool_hits + c.pool_misses).sum();
            let melems = m as f64 * iters as f64 / best / 1e6;
            t.row(&[
                tier.name().into(),
                format!("{}s", fmt_si(best)),
                format!("{melems:.1}"),
                format!("{:.1}", bytes as f64 / 1e6),
                rdv.to_string(),
                acq.to_string(),
            ]);
            let key = tier.name().replace('-', "_").to_lowercase();
            report.num(&format!("tier_{key}_wall_s"), best);
            report.num(&format!("tier_{key}_elems_per_sec"), m as f64 * iters as f64 / best);
            report.num(&format!("tier_{key}_bytes_copied"), bytes as f64);
            report.num(&format!("tier_{key}_rendezvous_hits"), rdv as f64);
            results.push((tier, best, bytes));
        }
        t.print();
        let (_, rdv_wall, rdv_bytes) = results[0];
        let (_, pooled_wall, pooled_bytes) = results[1];
        // Copy crediting is routed through the Transport trait; if any
        // backend or tier stops reporting, this ablation would silently
        // compare zeros.
        assert!(
            pooled_bytes > 0,
            "pooled tier must report copied payload bytes (bytes_copied crediting broke)"
        );
        let copy_ratio = pooled_bytes as f64 / rdv_bytes.max(1) as f64;
        let speedup = pooled_wall / rdv_wall;
        report.num("copy_ratio_pooled_over_rendezvous", copy_ratio);
        report.num("speedup_rendezvous_over_pooled", speedup);
        report.num("ablation_m", m as f64);
        report.num("ablation_p", p as f64);
        println!(
            "  rendezvous copies {copy_ratio:.2}× fewer payload bytes than pooled and runs {speedup:.2}× {}\n",
            if speedup >= 1.0 { "faster" } else { "slower (WARNING: expected a speedup)" }
        );
        // Quality gates: copy volume is deterministic — the zero-copy tier
        // must at least halve the bytes physically copied (it actually
        // only retains the allgather-phase Store scatters: expect ~3×).
        // Suspended under the process-wide kill-switch, which pins every
        // tier to pooled by design.
        if circulant_collectives::transport::rendezvous_env_enabled() {
            assert!(
                copy_ratio >= 2.0,
                "rendezvous path must copy ≥2× fewer payload bytes than pooled (got {copy_ratio:.2}×)"
            );
        }
    }

    // 4. PJRT combine per bucket -----------------------------------------
    match Engine::load(default_artifact_dir()) {
        Ok(engine) => {
            let mut t = Table::new(
                "PJRT combine (AOT Pallas kernel) per bucket",
                &["bucket", "median time", "Melem/s", "vs native sum"],
            );
            let buckets = engine.manifest.buckets.clone();
            // native reference at the largest bucket
            let nb = *buckets.last().unwrap();
            let mut accn = a0[..nb].to_vec();
            let nat = time_adaptive(0.05, reps, || {
                SumOp.combine(&mut accn, &b[..nb]);
                std::hint::black_box(&accn);
            });
            for &nbkt in &buckets {
                let mut acc = a0[..nbkt].to_vec();
                let s = time_adaptive(0.05, reps, || {
                    engine.combine_bucket_exact("sum", &mut acc, &b[..nbkt]).unwrap();
                    std::hint::black_box(&acc);
                });
                let native_equiv = nat.median * nbkt as f64 / nb as f64;
                t.row(&[
                    nbkt.to_string(),
                    format!("{}s", fmt_si(s.median)),
                    fmt_si(nbkt as f64 / s.median / 1e6),
                    format!("{:.1}× slower", s.median / native_equiv),
                ]);
            }
            t.print();
            // Large-request policy: combine_into chunks at the sweet spot
            // (CCOLL_PJRT_CHUNK to override; see §Perf iteration 1).
            let big = 300_000usize;
            let mut acc = a0[..big.min(n)].to_vec();
            let bb = b[..big.min(n)].to_vec();
            let s = time_adaptive(0.05, reps, || {
                engine.combine_into("sum", &mut acc, &bb, 0.0).unwrap();
                std::hint::black_box(&acc);
            });
            println!(
                "large request ({big} elems) via chunking policy: {}s = {} elem/s\n",
                fmt_si(s.median),
                fmt_si(big as f64 / s.median)
            );
            println!("(interpret-mode grid loops make big buckets slower per element, so");
            println!(" combine_into chunks at the measured sweet-spot bucket — §Perf log)\n");
        }
        Err(e) => println!("PJRT section skipped: {e}\n"),
    }

    // 5. threaded wall-clock vs CALIBRATED DES ---------------------------
    let ps = if fast_mode() { vec![2usize, 4] } else { vec![2usize, 4, 8, 12, 16] };
    let m = 1 << 18;
    let model = circulant_collectives::sim::calibrate::calibrate_transport(&SumOp, 2);
    println!(
        "calibrated transport model: α={:.2e}s β={:.2e}s/elem γ={:.2e}s/elem",
        model.alpha, model.beta, model.gamma
    );
    let mut wall = Vec::new();
    let mut des = Vec::new();
    // On a single physical core, p rank threads serialize: expect
    // wall ≈ DES · p (the DES assumes each rank has its own processor).
    let mut t =
        Table::new("threaded allreduce vs DES", &["p", "wall", "DES", "ratio", "ratio/p (1-core)"]);
    for &p in &ps {
        let part = BlockPartition::regular(p, m);
        let skips = SkipScheme::HalvingUp.skips(p).unwrap();
        let sched = allreduce_schedule(p, &skips);
        let mut rng = SplitMix64::new(p as u64);
        let inputs: Vec<Vec<f32>> = (0..p).map(|_| rng.normal_vec(m)).collect();
        let mut best = f64::INFINITY;
        for _ in 0..reps.max(3) {
            let t0 = std::time::Instant::now();
            let _ = run_schedule_threads(&sched, &part, Arc::new(SumOp), inputs.clone());
            best = best.min(t0.elapsed().as_secs_f64());
        }
        let sim = simulate(&sched, &part, &model).total;
        wall.push(best);
        des.push(sim);
        t.row(&[
            p.to_string(),
            format!("{}s", fmt_si(best)),
            format!("{}s", fmt_si(sim)),
            format!("{:.2}", best / sim),
            format!("{:.2}", best / (sim * p as f64)),
        ]);
    }
    t.print();
    if wall.len() > 2 {
        let r = pearson(&wall, &des);
        println!("wall vs DES Pearson r = {r:.3} (DES is a faithful relative predictor)");
        report.num("wall_vs_des_pearson_r", r);
    }

    // quality gates recorded in EXPERIMENTS.md §Perf. Shared CI runners
    // (2 vCPUs, noisy neighbors) get extra slack on the timing-derived
    // ratio; local runs keep the strict bound.
    let min_sum_ratio = if std::env::var("CI").is_ok() { 0.25 } else { 0.5 };
    assert!(
        sum_ratio > min_sum_ratio,
        "native sum below {:.0}% of streaming roofline: {sum_ratio:.2}",
        100.0 * min_sum_ratio
    );
    report.write();
}
