//! T4 — Corollary 3: irregular partitions (MPI_Reduce_scatter) and the
//! degenerate reduce-to-root.
//!
//! Workloads: uniform (reference), multinomial-random, zipf(1.5)-skewed,
//! and single-block (all m elements in one block — reduce-to-root).
//! For each: DES time vs Corollary 3's bound ⌈log2 p⌉(α+βm+γm) and vs the
//! regular-case Corollary 1 value, plus threaded correctness at small p.
//! Shape claim: cost degrades smoothly with skew, stays under the bound,
//! and the single-block case beats the ring-based reduce for small m.

use std::sync::Arc;

use circulant_collectives::bench_harness::{bench_header, fast_mode};
use circulant_collectives::collectives::{reduce_scatter_schedule, run_schedule_threads};
use circulant_collectives::datatypes::BlockPartition;
use circulant_collectives::ops::SumOp;
use circulant_collectives::sim::{closed_form, simulate, CostModel};
use circulant_collectives::topology::skips::SkipScheme;
use circulant_collectives::util::rng::SplitMix64;
use circulant_collectives::util::table::{fmt_si, Table};

fn check_threaded(part: &BlockPartition, seed: u64) -> bool {
    let p = part.p();
    let skips = SkipScheme::HalvingUp.skips(p).unwrap();
    let sched = reduce_scatter_schedule(p, &skips);
    let mut rng = SplitMix64::new(seed);
    let inputs: Vec<Vec<f32>> = (0..p).map(|_| rng.int_valued_vec(part.total(), -6, 7)).collect();
    let mut oracle = vec![0.0f32; part.total()];
    for v in &inputs {
        for (a, x) in oracle.iter_mut().zip(v) {
            *a += x;
        }
    }
    let outs = run_schedule_threads(&sched, part, Arc::new(SumOp), inputs);
    outs.iter().enumerate().all(|(r, buf)| buf[part.range(r)] == oracle[part.range(r)])
}

fn main() {
    bench_header("T4", "Corollary 3 — irregular reduce-scatter & reduce-to-root");
    let model = CostModel::cluster();
    let ps: Vec<usize> = if fast_mode() { vec![16] } else { vec![16, 100, 1024] };
    let m_factor = 1024usize;

    for &p in &ps {
        let m = p * m_factor;
        let workloads: Vec<(&str, BlockPartition)> = vec![
            ("uniform", BlockPartition::regular(p, m)),
            ("random", BlockPartition::random(p, m, 42)),
            ("zipf(1.0)", BlockPartition::zipf(p, m, 1.0, 43)),
            ("zipf(1.5)", BlockPartition::zipf(p, m, 1.5, 44)),
            ("single-block (reduce)", BlockPartition::single_block(p, m, p / 3)),
        ];
        let bound = closed_form::corollary3_bound(&model, p, m);
        let regular = closed_form::alg1_reduce_scatter(&model, p, m);
        let mut t = Table::new(
            &format!("T4: p={p}, m={m}"),
            &["workload", "max block", "DES time", "/Corollary 1", "≤ Corollary 3 bound", "threads ✓ (p≤16)"],
        );
        for (name, part) in &workloads {
            let skips = SkipScheme::HalvingUp.skips(p).unwrap();
            let sched = reduce_scatter_schedule(p, &skips);
            let sim = simulate(&sched, part, &model);
            assert!(
                sim.total <= bound * (1.0 + 1e-9),
                "{name}: {} exceeds Corollary 3 bound {}",
                sim.total,
                bound
            );
            let ok = if p <= 16 { check_threaded(part, p as u64) } else { true };
            assert!(ok, "{name} threaded check failed");
            t.row(&[
                name.to_string(),
                part.max_block().to_string(),
                format!("{}s", fmt_si(sim.total)),
                format!("{:.2}×", sim.total / regular),
                format!("{:.1}% of bound", 100.0 * sim.total / bound),
                if p <= 16 { "✓".into() } else { "—".to_string() },
            ]);
        }
        t.print();

        // Degenerate single-block = reduce-to-root: compare against the
        // linear-round alternative for a small vector (the regime §4 calls
        // attractive).
        let small_m = 512;
        let part = BlockPartition::single_block(p, small_m, 0);
        let skips = SkipScheme::HalvingUp.skips(p).unwrap();
        let circ = simulate(&reduce_scatter_schedule(p, &skips), &part, &model).total;
        let ring = (p - 1) as f64 * (model.alpha + (model.beta + model.gamma) * small_m as f64);
        println!(
            "reduce-to-root, m={small_m}: circulant {}s vs ring-style {}s ({}× faster)\n",
            fmt_si(circ),
            fmt_si(ring),
            (ring / circ).round()
        );
        assert!(circ < ring, "p={p}: small-m reduce should beat linear-round reduce");
    }
    println!("Corollary 3 bound holds across all workloads ✓");
}
