//! T10 — pipelined large-message tier: chunked vs plain allreduce.
//!
//! The ISSUE-9 acceptance gate: for ≥ 4 MiB sum-allreduces at p=8, the
//! engine's pipelined tier (working vector split into 256 KiB chunk
//! epochs, chunk k+1's sends overlapping chunk k's combines) must deliver
//! ≥ 1.5× the throughput of the same engine running the plain one-epoch
//! schedule, with bit-identical results in the wrapping integer dtypes.
//! Records achieved per-rank wire bandwidth (GiB/s) for both paths and
//! emits `BENCH_t10.json`.

use std::time::Instant;

use circulant_collectives::bench_harness::{bench_header, fast_mode, gib_per_sec, BenchReport};
use circulant_collectives::engine::{CollectiveEngine, EngineConfig, OpRequest};
use circulant_collectives::util::stats::Summary;
use circulant_collectives::util::table::{fmt_si, Table};

fn inputs_i64(p: usize, m: usize) -> Vec<Vec<i64>> {
    (0..p).map(|r| (0..m).map(|j| ((r * 31 + j) % 1000) as i64 - 500).collect()).collect()
}

fn oracle_i64(inputs: &[Vec<i64>]) -> Vec<i64> {
    let m = inputs[0].len();
    let mut acc = vec![0i64; m];
    for v in inputs {
        for (a, x) in acc.iter_mut().zip(v) {
            *a = a.wrapping_add(*x);
        }
    }
    acc
}

/// Run `reps` back-to-back sum-allreduces through `engine`, verifying
/// every output bit-exactly against `want`. Returns per-op seconds.
fn run_ops(
    engine: &mut CollectiveEngine<i64>,
    inputs: &[Vec<i64>],
    want: &[i64],
    reps: usize,
) -> Vec<f64> {
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        let out =
            engine.submit(OpRequest::allreduce(inputs.to_vec(), "sum")).unwrap().wait().unwrap();
        times.push(t0.elapsed().as_secs_f64());
        for (r, buf) in out.iter().enumerate() {
            assert!(buf[..] == want[..], "rank {r}: allreduce result is not bit-identical");
        }
    }
    times
}

fn main() {
    bench_header("T10", "pipelined large-message tier — chunked vs plain allreduce");
    let p = 8usize;
    let chunk_bytes = 1usize << 18; // 256 KiB chunk epochs
    // ≥ 4 MiB payloads: the bandwidth-bound regime the tier exists for.
    let sizes: Vec<usize> = if fast_mode() {
        vec![1 << 19] // 512 Ki i64 = 4 MiB
    } else {
        vec![1 << 19, 1 << 20, 1 << 21] // 4, 8, 16 MiB
    };
    let reps: usize = if fast_mode() { 5 } else { 9 };

    let mut report = BenchReport::new("t10");
    report.str("dtype", "i64");
    report.num("p", p as f64);
    report.num("chunk_bytes", chunk_bytes as f64);
    report.num("reps", reps as f64);
    report.nums("sweep_m", sizes.iter().map(|&m| m as f64));

    let mut plain_lat = Vec::new();
    let mut piped_lat = Vec::new();
    let mut plain_bw = Vec::new();
    let mut piped_bw = Vec::new();
    let mut speedups = Vec::new();

    let mut t = Table::new(
        &format!("i64 sum-allreduce, p={p}, 256 KiB chunks (median of {reps} reps)"),
        &["m (elems)", "MiB", "plain s", "pipelined s", "plain GiB/s", "piped GiB/s", "speedup"],
    );

    for &m in &sizes {
        let inputs = inputs_i64(p, m);
        let want = oracle_i64(&inputs);
        let bytes = m * std::mem::size_of::<i64>();
        // Per-rank wire volume of Algorithm 2: 2(p−1)/p·m elements.
        let wire_bytes = 2 * (p - 1) * bytes / p;

        // --- plain: the pipelined tier disabled (min_bytes = 0) -------
        let mut engine: CollectiveEngine<i64> =
            CollectiveEngine::new(EngineConfig::new(p).pipeline_min_bytes(0));
        run_ops(&mut engine, &inputs, &want, 2); // warm-up
        let plain = Summary::of(&run_ops(&mut engine, &inputs, &want, reps));
        assert_eq!(engine.fusion_stats().pipelined_ops, 0, "plain engine must never chunk");
        engine.shutdown();

        // --- pipelined: same engine, tier forced on for this payload --
        let mut engine: CollectiveEngine<i64> = CollectiveEngine::new(
            EngineConfig::new(p).pipeline_min_bytes(1).pipeline_chunk_bytes(chunk_bytes),
        );
        run_ops(&mut engine, &inputs, &want, 2); // warm-up
        let piped = Summary::of(&run_ops(&mut engine, &inputs, &want, reps));
        let pstats = engine.fusion_stats();
        engine.shutdown();
        assert!(pstats.pipelined_ops >= (reps + 2) as u64, "m={m}: ops were not pipelined");

        let speedup = plain.median / piped.median;
        t.row(&[
            m.to_string(),
            (bytes >> 20).to_string(),
            fmt_si(plain.median),
            fmt_si(piped.median),
            format!("{:.2}", gib_per_sec(wire_bytes, plain.median)),
            format!("{:.2}", gib_per_sec(wire_bytes, piped.median)),
            format!("{speedup:.2}×"),
        ]);
        plain_lat.push(plain.median);
        piped_lat.push(piped.median);
        plain_bw.push(gib_per_sec(wire_bytes, plain.median));
        piped_bw.push(gib_per_sec(wire_bytes, piped.median));
        speedups.push(speedup);

        // The acceptance gate (per size, all ≥ 4 MiB): pipelined ≥ 1.5×.
        assert!(
            speedup >= 1.5,
            "m={m} ({} MiB): pipelining only {speedup:.2}× the plain run \
             ({} s vs {} s) — acceptance requires ≥ 1.5×",
            bytes >> 20,
            fmt_si(piped.median),
            fmt_si(plain.median),
        );
    }
    t.print();

    // Bit-identity in a second integer dtype (untimed): u64 wraps the
    // same schedule through the pipelined tier.
    {
        let m = 1 << 19;
        let inputs: Vec<Vec<u64>> =
            (0..p).map(|r| (0..m).map(|j| (r as u64) << 32 | j as u64).collect()).collect();
        let mut want = vec![0u64; m];
        for v in &inputs {
            for (a, x) in want.iter_mut().zip(v) {
                *a = a.wrapping_add(*x);
            }
        }
        let mut engine: CollectiveEngine<u64> = CollectiveEngine::new(
            EngineConfig::new(p).pipeline_min_bytes(1).pipeline_chunk_bytes(chunk_bytes),
        );
        let out = engine.submit(OpRequest::allreduce(inputs, "sum")).unwrap().wait().unwrap();
        assert!(engine.fusion_stats().pipelined_ops == 1);
        engine.shutdown();
        for (r, buf) in out.iter().enumerate() {
            assert!(buf[..] == want[..], "rank {r}: u64 pipelined result not bit-identical");
        }
        println!("u64 bit-identity through the pipelined tier: ✓");
    }

    let min_speedup = speedups.iter().cloned().fold(f64::INFINITY, f64::min);
    println!(
        "pipelined tier: chunked execution beats the plain schedule by ≥ {min_speedup:.2}× \
         for every payload ≥ 4 MiB at p={p}, bit-identical in i64/u64 — combine/communication \
         overlap over the circulant schedule REPRODUCED"
    );
    report.nums("plain_latency_s", plain_lat);
    report.nums("pipelined_latency_s", piped_lat);
    report.nums("plain_gib_s", plain_bw);
    report.nums("pipelined_gib_s", piped_bw);
    report.nums("speedup", speedups);
    report.num("min_speedup", min_speedup);
    report.num("gate_speedup", 1.5);
    report.write();
}
