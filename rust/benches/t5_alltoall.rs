//! T5 — §4: all-to-all via the concatenation reduce-scatter.
//!
//! Measured on the thread network: round count ⌈log2 p⌉, per-rank payload
//! volume vs the (m/2)·⌈log2 p⌉ model and vs direct exchange (p−1 rounds,
//! (p−1)/p·m volume), correctness vs the transpose oracle, and wall-clock.

use std::sync::Arc;

use circulant_collectives::bench_harness::{bench_header, fast_mode, time_reps};
use circulant_collectives::collectives::alltoall::{alltoall_rank, alltoall_send_volume};
use circulant_collectives::datatypes::BlockPartition;
use circulant_collectives::topology::skips::SkipScheme;
use circulant_collectives::transport::run_ranks;
use circulant_collectives::util::ceil_log2;
use circulant_collectives::util::stats::Summary;
use circulant_collectives::util::table::{fmt_si, Table};

fn run_once(p: usize, block: usize) -> (bool, u64, u64) {
    let part = BlockPartition::uniform(p, block);
    let skips = SkipScheme::HalvingUp.skips(p).unwrap();
    let part2 = Arc::new(part.clone());
    let skips2 = Arc::new(skips);
    let outs = run_ranks(p, move |rank, ep| {
        let input: Vec<f32> =
            (0..part2.total()).map(|j| (rank * 100_000 + j) as f32).collect();
        let out = alltoall_rank(ep, &part2, &skips2, &input, 0).unwrap();
        (out, ep.counters.clone())
    });
    // verify transpose semantics
    let mut ok = true;
    for (r, (out, _)) in outs.iter().enumerate() {
        for g in 0..p {
            for j in 0..block {
                let want = (g * 100_000 + r * block + j) as f32;
                if out[g * block + j] != want {
                    ok = false;
                }
            }
        }
    }
    let c = &outs[0].1;
    (ok, c.sendrecv_rounds, c.elems_sent)
}

fn main() {
    bench_header("T5", "§4 — all-to-all on the circulant schedule");
    let ps: Vec<usize> = if fast_mode() { vec![8, 22] } else { vec![4, 8, 16, 22, 32, 64] };
    let block = 64usize;

    let mut t = Table::new(
        &format!("T5: all-to-all, {} f32 per pairwise block", block),
        &["p", "rounds", "⌈log2 p⌉", "elems sent/rank", "model (m/2)·q", "direct-exchange vol", "correct", "wall"],
    );
    for &p in &ps {
        let m = p * block;
        let (ok, rounds, elems) = run_once(p, block);
        assert!(ok, "p={p} transpose mismatch");
        assert_eq!(rounds as u32, ceil_log2(p));
        let part = BlockPartition::uniform(p, block);
        let skips = SkipScheme::HalvingUp.skips(p).unwrap();
        let predicted = alltoall_send_volume(&part, &skips);
        let samples = time_reps(1, if fast_mode() { 3 } else { 5 }, || {
            let _ = run_once(p, block);
        });
        t.row(&[
            p.to_string(),
            rounds.to_string(),
            ceil_log2(p).to_string(),
            elems.to_string(),
            predicted.to_string(),
            ((p - 1) * block).to_string(),
            "✓".into(),
            format!("{}s", fmt_si(Summary::of(&samples).median)),
        ]);
        // payload (excluding framing) should track the subtree model within
        // the framing overhead (3 header floats per entry)
        let q = ceil_log2(p) as f64;
        assert!((elems as f64) < 1.8 * (m as f64) / 2.0 * q + 64.0, "p={p} volume blowup");
    }
    t.print();
    println!("claim (§4): all-to-all in ⌈log2 p⌉ rounds via ⊕=concatenation — REPRODUCED;");
    println!("volume grows to ≈(m/2)·⌈log2 p⌉ per rank, the usual dissemination trade-off.");
}
