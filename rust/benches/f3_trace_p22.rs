//! F3 — the paper's §2.1 worked example, reproduced term for term.
//!
//! p = 22, processor r = 21, halving-up skips 11, 6, 3, 2, 1. The paper
//! lists the from-processors (10, 15, 18, 19, 20) and the exact partial
//! sums W accumulates per round. We execute the schedule symbolically and
//! assert every term, then sweep all 22 ranks and verify each receives all
//! 22 contributions exactly once in the same rank-relative order.

use circulant_collectives::bench_harness::bench_header;
use circulant_collectives::analysis as symbolic;
use circulant_collectives::collectives::reduce_scatter_schedule;
use circulant_collectives::topology::skips::SkipScheme;
use circulant_collectives::topology::Circulant;

fn main() {
    bench_header("F3", "§2.1 worked example — p=22 trace");
    let p = 22;
    let r = 21;
    let skips = SkipScheme::HalvingUp.skips(p).unwrap();
    assert_eq!(skips, vec![11, 6, 3, 2, 1], "paper's skip sequence");
    println!("skips: {skips:?}  (⌈log2 22⌉ = {} rounds)", skips.len());

    let g = Circulant::new(p, skips.clone());
    let from = g.in_neighbors(r);
    println!("from-processors of r={r}: {from:?}");
    assert_eq!(from, vec![10, 15, 18, 19, 20], "paper's from-list");

    let sched = reduce_scatter_schedule(p, &skips);
    let terms = symbolic::paper_example_terms(&sched, r);
    println!("\nW = {}", terms[0]);
    for (k, t) in terms[1..].iter().enumerate() {
        println!("  + {t}    ← round {} from processor {}", k + 1, from[k]);
    }

    // The paper's five received partial sums (its displayed equation):
    let expected = [
        "x10",
        "(x15 + x4)",
        "((x18 + x7) + (x12 + x1))",
        "(((x19 + x8) + (x13 + x2)) + (x16 + x5))",
        "(((x20 + x9) + (x14 + x3)) + ((x17 + x6) + (x11 + x0)))",
    ];
    for (k, want) in expected.iter().enumerate() {
        assert_eq!(&terms[k + 1], want, "round {} term", k + 1);
    }
    println!("\nall 5 round terms match the paper's equation ✓");

    // Every rank, same structure.
    let depth = symbolic::verify_reduce_scatter(&sched).expect("symbolic correctness");
    let state = symbolic::run_symbolic(&sched);
    let rel: Vec<usize> = state[0][0].leaves().iter().map(|&x| (p - x) % p).collect();
    for rr in 1..p {
        let rel_r: Vec<usize> =
            state[rr][rr].leaves().iter().map(|&x| (rr + p - x) % p).collect();
        assert_eq!(rel_r, rel, "rank {rr} applies ⊕ in a different order");
    }
    println!("all 22 ranks reduce in the same rank-relative order (commutativity used uniformly) ✓");
    println!("max combine-tree depth: {depth}");
}
