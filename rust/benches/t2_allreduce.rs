//! T2 — Theorem 2: Algorithm 2 (allreduce) rounds & volume, uniform in p.
//!
//! Measured on the thread network with instrumented endpoints:
//! `2⌈log2 p⌉` rounds, `2(p−1)` blocks sent and received, exactly `p−1`
//! ⊕-applications per processor; result replicated and exact on all ranks.
//! DES time must equal Theorem 2's closed form. Also cross-checks the
//! volume bound of [3,15] (2(p−1) blocks is optimal when the reduction
//! work is balanced).
//!
//! Generic over the element type: `CCOLL_BENCH_DTYPE` (f32|f64|i32|i64|u64,
//! default f32) selects the dtype the payloads travel in; the JSON report
//! records it in the `dtype` field. Verification is exact in every dtype
//! (wrapping integer ⊕; small-integer-valued float inputs).

use std::sync::Arc;

use circulant_collectives::bench_harness::{
    bench_dtype, bench_header, fast_mode, gib_per_sec, BenchReport,
};
use circulant_collectives::collectives::allreduce_schedule;
use circulant_collectives::datatypes::{elem, BlockPartition, DType, Elem};
use circulant_collectives::ops::SumOp;
use circulant_collectives::sim::{closed_form, simulate, CostModel};
use circulant_collectives::topology::skips::SkipScheme;
use circulant_collectives::util::ceil_log2;
use circulant_collectives::util::rng::SplitMix64;
use circulant_collectives::util::table::Table;

fn main() {
    let dt = bench_dtype();
    bench_header("T2", "Theorem 2 — allreduce rounds & volume, uniform in p");
    match dt {
        DType::F32 => sweep::<f32>(),
        DType::F64 => sweep::<f64>(),
        DType::I32 => sweep::<i32>(),
        DType::I64 => sweep::<i64>(),
        DType::U64 => sweep::<u64>(),
    }
}

fn sweep<T: Elem>() {
    let ps: Vec<usize> = if fast_mode() {
        vec![2, 5, 22]
    } else {
        vec![2, 3, 4, 6, 8, 11, 16, 22, 27, 32, 45, 64, 100, 128]
    };
    let b = 64;
    let model = CostModel::new(1.0, 1e-3, 1e-4);
    let (lo, hi) = elem::test_value_bounds(T::DTYPE);

    let mut t = Table::new(
        &format!("Theorem 2 (measured, b=64 {}/block)", T::DTYPE.name()),
        &["p", "rounds", "2⌈log2 p⌉", "blocks/rank", "2(p−1)", "⊕ blocks", "p−1", "DES=Thm2", "verified"],
    );
    let mut report = BenchReport::new("t2");
    report.str("dtype", T::DTYPE.name());
    let mut rounds_meas = Vec::new();
    let mut blocks_meas = Vec::new();
    let mut combines_meas = Vec::new();
    let mut bw_meas = Vec::new();
    let mut all_ok = true;
    for &p in &ps {
        let skips = SkipScheme::HalvingUp.skips(p).unwrap();
        let sched = allreduce_schedule(p, &skips);
        sched.assert_valid();
        let part = BlockPartition::uniform(p, b);

        let mut rng = SplitMix64::new(1000 + p as u64);
        let inputs: Vec<Vec<T>> =
            (0..p).map(|_| elem::int_vec(&mut rng, part.total(), lo, hi)).collect();
        let mut oracle = vec![T::zero(); part.total()];
        for v in &inputs {
            SumOp.combine(&mut oracle, v);
        }
        let sched2 = Arc::new(sched.clone());
        let part2 = Arc::new(part.clone());
        let t0 = std::time::Instant::now();
        let outs = circulant_collectives::transport::run_ranks_inputs_typed::<T, _, _, _>(
            inputs,
            move |_rank, ep, mut buf: Vec<T>| {
                circulant_collectives::collectives::execute_rank(
                    ep, &sched2, &part2, &SumOp, &mut buf, 0,
                )
                .unwrap();
                (buf, ep.counters.clone())
            },
        );
        let wall = t0.elapsed().as_secs_f64();

        let verified = outs.iter().all(|(buf, _)| buf[..] == oracle[..]);
        all_ok &= verified;
        let c0 = &outs[0].1;
        let sc = sched.counters(&part);
        let sim = simulate(&sched, &part, &model);
        let cf = closed_form::alg2_allreduce(&model, p, part.total());
        let exact = (sim.total - cf).abs() < 1e-9 * cf.max(1.0);
        all_ok &= exact;

        t.row(&[
            p.to_string(),
            c0.sendrecv_rounds.to_string(),
            (2 * ceil_log2(p)).to_string(),
            sc[0].blocks_sent.to_string(),
            (2 * (p - 1)).to_string(),
            sc[0].blocks_combined.to_string(),
            (p - 1).to_string(),
            if exact { "=".into() } else { "≠".to_string() },
            if verified { "✓".into() } else { "FAIL".to_string() },
        ]);
        assert_eq!(c0.sendrecv_rounds as u32, 2 * ceil_log2(p));
        assert_eq!(sc[0].blocks_sent, 2 * (p - 1));
        assert_eq!(sc[0].blocks_combined, p - 1);
        rounds_meas.push(c0.sendrecv_rounds as f64);
        blocks_meas.push(sc[0].blocks_sent as f64);
        combines_meas.push(sc[0].blocks_combined as f64);
        // Achieved per-rank wire bandwidth: rank 0's payload bytes over
        // the whole-run wall clock (thread spawn included — honest
        // end-to-end, not a peak-rate claim).
        bw_meas.push(gib_per_sec(c0.elems_sent as usize * std::mem::size_of::<T>(), wall));
    }
    t.print();
    println!(
        "paper claim: 2⌈log2 p⌉ rounds, 2(p−1) blocks, p−1 reductions (optimal [3,15]) — {}",
        if all_ok { "REPRODUCED" } else { "MISMATCH" }
    );
    assert!(all_ok);
    report.num("block_elems", b as f64);
    report.nums("sweep_p", ps.iter().map(|&p| p as f64));
    report.nums("rounds_measured", rounds_meas);
    report.nums("blocks_sent_per_rank", blocks_meas);
    report.nums("blocks_combined_per_rank", combines_meas);
    report.nums("bandwidth_gib_s", bw_meas);
    report.num("all_verified", if all_ok { 1.0 } else { 0.0 });
    report.write();
}
