//! T6 (extension) — §3's clustered-systems remark, made measurable.
//!
//! "The doubling and halving schemes lead to latency contention and
//! communication redundancy when run as written on clustered,
//! hierarchical systems with constrained per node bandwidth [21]."
//!
//! Under the two-level cost model with per-node link contention
//! (`sim::hier`), compare flat Algorithm 2 against the decomposed
//! schedule (intra-node reduce → leader circulant allreduce → intra-node
//! bcast, `collectives::hierarchical`), sweeping m and node size.
//! Expected shape: flat wins when nodes are tiny or vectors small (fewer
//! rounds, no redundancy); decomposition wins once every rank of a node
//! contends for one NIC on large vectors.

use circulant_collectives::bench_harness::{bench_header, fast_mode};
use circulant_collectives::collectives::hierarchical::hierarchical_allreduce_schedule;
use circulant_collectives::collectives::Algorithm;
use circulant_collectives::datatypes::BlockPartition;
use circulant_collectives::sim::hier::{simulate_hier, HierModel};
use circulant_collectives::topology::skips::SkipScheme;
use circulant_collectives::util::table::{fmt_si, Table};

fn main() {
    bench_header("T6", "hierarchical decomposition vs flat Algorithm 2 (§3/[21])");
    let p = 64;
    let node_sizes: Vec<usize> = if fast_mode() { vec![8] } else { vec![2, 4, 8, 16] };
    let ms: Vec<usize> =
        if fast_mode() { vec![1 << 16] } else { (8..=24).step_by(2).map(|e| 1usize << e).collect() };

    for &node in &node_sizes {
        let model = HierModel::typical(node);
        let flat = Algorithm::parse("ar").unwrap().schedule(p);
        let hier = hierarchical_allreduce_schedule(p, node, &SkipScheme::HalvingUp);
        hier.assert_valid();
        let mut t = Table::new(
            &format!("T6: p={p}, node_size={node} (typical cluster: 0.2µs/40GB·s intra, 2µs/10GB·s inter, NIC contention)"),
            &["m", "flat Alg 2", "decomposed", "speedup", "winner"],
        );
        let mut crossover = None;
        for &m in &ms {
            let part = BlockPartition::regular(p, m);
            let tf = simulate_hier(&flat, &part, &model).total;
            let th = simulate_hier(&hier, &part, &model).total;
            if th < tf && crossover.is_none() {
                crossover = Some(m);
            }
            t.row(&[
                fmt_si(m as f64),
                format!("{}s", fmt_si(tf)),
                format!("{}s", fmt_si(th)),
                format!("{:.2}×", tf / th),
                if th < tf { "decomposed".into() } else { "flat".to_string() },
            ]);
        }
        t.print();
        if let Some(m) = crossover {
            println!("decomposition pays off from m ≈ {}\n", fmt_si(m as f64));
        } else {
            println!("flat Algorithm 2 wins across the sweep at node_size={node}\n");
        }
    }

    // Shape assertion: at node=8 and a large vector, decomposition must win.
    let node = 8;
    let model = HierModel::typical(node);
    let part = BlockPartition::regular(p, 1 << 22);
    let tf = simulate_hier(&Algorithm::parse("ar").unwrap().schedule(p), &part, &model).total;
    let th = simulate_hier(
        &hierarchical_allreduce_schedule(p, node, &SkipScheme::HalvingUp),
        &part,
        &model,
    )
    .total;
    assert!(th < tf, "decomposed {th} should beat flat {tf} at m=2^22");
    println!("shape check ✓ (contended flat halving/doubling loses to decomposition — §3's warning)");
}
