//! T1 — Theorem 1: Algorithm 1 is round- and volume-optimal for every p.
//!
//! For a sweep of p (powers of two, neighbors of powers of two, the
//! paper's p=22, and assorted odd values) this bench:
//!   * executes Algorithm 1 on the thread network with instrumented
//!     endpoints and a counting ⊕, reporting measured rounds / blocks /
//!     ⊕-applications against the theorem's ⌈log2 p⌉ and p−1;
//!   * verifies the result against a scalar oracle (exact in every dtype:
//!     integer dtypes reduce with wrapping — hence exactly associative —
//!     arithmetic, and float inputs are small-integer-valued so sums stay
//!     exactly representable);
//!   * checks the DES time against Corollary 1's closed form (exact in the
//!     model).
//!
//! Generic over the element type: `CCOLL_BENCH_DTYPE` (f32|f64|i32|i64|u64,
//! default f32) selects the dtype the payloads travel in; the JSON report
//! records it in the `dtype` field.
//!
//! Regenerates the "Theorem 1" table of EXPERIMENTS.md.

use std::sync::Arc;

use circulant_collectives::bench_harness::{
    bench_dtype, bench_header, fast_mode, gib_per_sec, BenchReport,
};
use circulant_collectives::collectives::reduce_scatter_schedule;
use circulant_collectives::datatypes::{elem, BlockPartition, DType, Elem};
use circulant_collectives::ops::SumOp;
use circulant_collectives::sim::{closed_form, simulate, CostModel};
use circulant_collectives::topology::skips::SkipScheme;
use circulant_collectives::util::ceil_log2;
use circulant_collectives::util::rng::SplitMix64;
use circulant_collectives::util::table::Table;

fn main() {
    let dt = bench_dtype();
    bench_header("T1", "Theorem 1 — reduce-scatter rounds & volume, uniform in p");
    match dt {
        DType::F32 => sweep::<f32>(),
        DType::F64 => sweep::<f64>(),
        DType::I32 => sweep::<i32>(),
        DType::I64 => sweep::<i64>(),
        DType::U64 => sweep::<u64>(),
    }
}

fn sweep<T: Elem>() {
    let ps: Vec<usize> = if fast_mode() {
        vec![2, 3, 8, 22]
    } else {
        vec![2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 22, 31, 32, 33, 63, 64, 65, 100, 127, 128, 129]
    };
    let b = 257; // elements per block (odd on purpose)
    let model = CostModel::new(1.0, 1e-3, 1e-4); // unit-ish for exact checks
    let (lo, hi) = elem::test_value_bounds(T::DTYPE);

    let mut t = Table::new(
        &format!("Theorem 1 (measured on the thread network, b=257 {}/block)", T::DTYPE.name()),
        &[
            "p",
            "rounds (meas)",
            "⌈log2 p⌉",
            "blocks sent/rank",
            "p−1",
            "⊕ blocks/rank",
            "DES time",
            "Corollary 1",
            "verified",
        ],
    );

    let mut report = BenchReport::new("t1");
    report.str("dtype", T::DTYPE.name());
    let mut rounds_meas = Vec::new();
    let mut blocks_meas = Vec::new();
    let mut elems_sent_meas = Vec::new();
    let mut bw_meas = Vec::new();
    let mut all_ok = true;
    for &p in &ps {
        let skips = SkipScheme::HalvingUp.skips(p).unwrap();
        let sched = reduce_scatter_schedule(p, &skips);
        sched.assert_valid();
        let part = BlockPartition::uniform(p, b);

        // --- instrumented threaded execution --------------------------
        let mut rng = SplitMix64::new(p as u64);
        let inputs: Vec<Vec<T>> =
            (0..p).map(|_| elem::int_vec(&mut rng, part.total(), lo, hi)).collect();
        let mut oracle = vec![T::zero(); part.total()];
        for v in &inputs {
            SumOp.combine(&mut oracle, v);
        }
        let sched2 = Arc::new(sched.clone());
        let part2 = Arc::new(part.clone());
        let t0 = std::time::Instant::now();
        let outs = circulant_collectives::transport::run_ranks_inputs_typed::<T, _, _, _>(
            inputs,
            move |_rank, ep, mut buf: Vec<T>| {
                circulant_collectives::collectives::execute_rank(
                    ep, &sched2, &part2, &SumOp, &mut buf, 0,
                )
                .unwrap();
                (buf, ep.counters.clone())
            },
        );
        let wall = t0.elapsed().as_secs_f64();

        let mut verified = true;
        for (r, (buf, _)) in outs.iter().enumerate() {
            if buf[part.range(r)] != oracle[part.range(r)] {
                verified = false;
            }
        }
        all_ok &= verified;
        let c0 = &outs[0].1;
        let counters = sched.counters(&part);
        let blocks_sent = counters[0].blocks_sent;
        let combines = counters[0].blocks_combined;
        assert!(counters.iter().all(|c| c.blocks_sent == blocks_sent));

        // --- DES vs closed form ----------------------------------------
        let sim = simulate(&sched, &part, &model);
        let cf = closed_form::alg1_reduce_scatter(&model, p, part.total());
        let exact = (sim.total - cf).abs() < 1e-9 * cf.max(1.0);
        all_ok &= exact;

        t.row(&[
            p.to_string(),
            c0.sendrecv_rounds.to_string(),
            ceil_log2(p).to_string(),
            blocks_sent.to_string(),
            (p - 1).to_string(),
            combines.to_string(),
            format!("{:.6}", sim.total),
            format!("{:.6}{}", cf, if exact { " =" } else { " ≠" }),
            if verified { "✓".into() } else { "FAIL".to_string() },
        ]);

        assert_eq!(c0.sendrecv_rounds as u32, ceil_log2(p), "p={p} rounds");
        assert_eq!(blocks_sent, p - 1, "p={p} blocks");
        assert_eq!(combines, p - 1, "p={p} combines");
        rounds_meas.push(c0.sendrecv_rounds as f64);
        blocks_meas.push(blocks_sent as f64);
        elems_sent_meas.push(c0.elems_sent as f64);
        // Achieved per-rank wire bandwidth: rank 0's payload bytes over
        // the whole-run wall clock (thread spawn included — honest
        // end-to-end, not a peak-rate claim).
        bw_meas.push(gib_per_sec(c0.elems_sent as usize * std::mem::size_of::<T>(), wall));
    }
    t.print();
    println!("paper claim: ⌈log2 p⌉ rounds, exactly p−1 blocks sent/received/reduced — {}",
        if all_ok { "REPRODUCED for all p in sweep" } else { "MISMATCH (see table)" });
    assert!(all_ok);
    report.num("block_elems", b as f64);
    report.nums("sweep_p", ps.iter().map(|&p| p as f64));
    report.nums("rounds_measured", rounds_meas);
    report.nums("blocks_sent_per_rank", blocks_meas);
    report.nums("elems_sent_rank0", elems_sent_meas);
    report.nums("bandwidth_gib_s", bw_meas);
    report.num("all_verified", if all_ok { 1.0 } else { 0.0 });
    report.write();
}
