//! T8 — engine amortization: persistent rank workers vs spawn-per-call.
//!
//! The ISSUE-4 acceptance gate: for small (≤ 4 KiB) repeated allreduces
//! at p=8, a warm [`CollectiveEngine`] (workers + endpoint network +
//! plan cache all persistent) must beat the cold path (a full
//! `Launcher::run` per operation: p thread spawns, fresh endpoints,
//! fresh schedule) by ≥ 2× per-op latency. Also reports pipelined
//! throughput with a window of in-flight operations, plan-cache
//! hit rates, and the thread-spawn ledger. Emits `BENCH_t8.json`.

use circulant_collectives::bench_harness::{bench_header, fast_mode, time_reps, BenchReport};
use circulant_collectives::coordinator::Launcher;
use circulant_collectives::engine::{CollectiveEngine, EngineConfig, OpRequest};
use circulant_collectives::transport::rank_threads_spawned;
use circulant_collectives::util::stats::Summary;
use circulant_collectives::util::table::{fmt_si, Table};

fn main() {
    bench_header("T8", "persistent engine vs spawn-per-call — per-op latency amortization");
    let p = 8usize;
    // Element counts ≤ 1024 f32 = ≤ 4 KiB payloads — the regime where
    // per-op overhead dominates and amortization matters most.
    let sizes: Vec<usize> = if fast_mode() { vec![64, 1024] } else { vec![64, 256, 1024] };
    let (cold_reps, warm_reps) = if fast_mode() { (10, 300) } else { (30, 1500) };

    let mut report = BenchReport::new("t8");
    report.num("p", p as f64);
    report.nums("sweep_m", sizes.iter().map(|&m| m as f64));
    let mut cold_us = Vec::new();
    let mut warm_us = Vec::new();
    let mut speedups = Vec::new();
    let mut pipelined_ops_per_sec = Vec::new();

    let mut t = Table::new(
        &format!("repeated f32 sum-allreduce, p={p} (medians)"),
        &["m (elems)", "bytes", "cold/op", "warm/op", "speedup", "pipelined ops/s"],
    );

    for &m in &sizes {
        let inputs: Vec<Vec<f32>> =
            (0..p).map(|r| (0..m).map(|j| ((r + j) % 7) as f32).collect()).collect();
        let want: Vec<f32> =
            (0..m).map(|j| (0..p).map(|r| ((r + j) % 7) as f32).sum()).collect();

        // --- cold: full Launcher::run per op (spawns p threads every
        // time — exactly what pre-engine callers did) -----------------
        let cold_inputs = inputs.clone();
        let cold_want = want.clone();
        let cold = Summary::of(&time_reps(2, cold_reps, move || {
            let ins = std::sync::Arc::new(std::sync::Mutex::new(
                cold_inputs.clone().into_iter().map(Some).collect::<Vec<_>>(),
            ));
            let out = Launcher::new(p).run(move |mut comm| {
                let mut buf = ins.lock().unwrap()[comm.rank()].take().unwrap();
                comm.allreduce(&mut buf, "sum").unwrap();
                buf
            });
            assert_eq!(out[0], cold_want);
        }));

        // --- warm: one persistent engine, sequential submit → wait ----
        let spawned_before = rank_threads_spawned();
        let mut engine: CollectiveEngine<f32> = CollectiveEngine::new(EngineConfig::new(p));
        let warm_inputs = inputs.clone();
        let warm_want = want.clone();
        let warm = {
            let engine = &mut engine;
            Summary::of(&time_reps(20, warm_reps, move || {
                let out = engine
                    .submit(OpRequest::allreduce(warm_inputs.clone(), "sum"))
                    .unwrap()
                    .wait()
                    .unwrap();
                assert_eq!(out[0], warm_want);
            }))
        };

        // --- warm, pipelined: window of 8 in-flight ops ---------------
        let pipe_ops = if fast_mode() { 400 } else { 2000 };
        let t0 = std::time::Instant::now();
        let mut window = std::collections::VecDeque::new();
        for _ in 0..pipe_ops {
            window.push_back(engine.submit(OpRequest::allreduce(inputs.clone(), "sum")).unwrap());
            if window.len() >= 8 {
                window.pop_front().unwrap().wait().unwrap();
            }
        }
        while let Some(h) = window.pop_front() {
            h.wait().unwrap();
        }
        let pipe_rate = pipe_ops as f64 / t0.elapsed().as_secs_f64();
        let stats = engine.plan_stats();
        engine.shutdown();
        let engine_spawned = rank_threads_spawned() - spawned_before;
        assert_eq!(
            engine_spawned, p as u64,
            "m={m}: warm engine must spawn exactly p threads for its whole lifetime"
        );
        assert!(
            stats.hits as usize >= warm_reps + pipe_ops,
            "m={m}: repeated identical ops must hit the plan cache ({} hits)",
            stats.hits
        );

        let speedup = cold.median / warm.median;
        t.row(&[
            m.to_string(),
            (4 * m).to_string(),
            format!("{}s", fmt_si(cold.median)),
            format!("{}s", fmt_si(warm.median)),
            format!("{speedup:.1}×"),
            fmt_si(pipe_rate),
        ]);
        cold_us.push(cold.median * 1e6);
        warm_us.push(warm.median * 1e6);
        speedups.push(speedup);
        pipelined_ops_per_sec.push(pipe_rate);

        // The acceptance gate (per size, all ≤ 4 KiB): warm ≥ 2× cold.
        assert!(
            speedup >= 2.0,
            "m={m} ({} B): warm engine only {speedup:.2}× faster than spawn-per-call \
             (cold {:.1}µs vs warm {:.1}µs) — acceptance requires ≥ 2×",
            4 * m,
            cold.median * 1e6,
            warm.median * 1e6,
        );
    }
    t.print();
    let min_speedup = speedups.iter().cloned().fold(f64::INFINITY, f64::min);
    println!(
        "engine amortization: warm-engine per-op latency beats cold spawn-per-call by \
         ≥ {min_speedup:.1}× for every payload ≤ 4 KiB at p={p} — spawn-once, plan-cached, \
         pool-warm serving path REPRODUCED"
    );
    report.nums("cold_us", cold_us);
    report.nums("warm_us", warm_us);
    report.nums("speedup", speedups);
    report.nums("pipelined_ops_per_sec", pipelined_ops_per_sec);
    report.num("min_speedup", min_speedup);
    report.num("gate_speedup", 2.0);
    report.write();
}
