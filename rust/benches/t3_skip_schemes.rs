//! T3 — Corollary 2: skip-scheme study (the paper's open question).
//!
//! For each scheme (halving-up, power-of-two, √p, fully-connected) and
//! several p: rounds, max message run, DES time in three α-β-γ regimes,
//! plus measured wall-clock of real threaded execution at small p.
//! Property verified throughout: every valid scheme moves exactly p−1
//! blocks per rank (volume optimality is scheme-independent).

use std::sync::Arc;

use circulant_collectives::bench_harness::{bench_header, fast_mode, time_reps};
use circulant_collectives::collectives::{reduce_scatter_schedule, run_schedule_threads};
use circulant_collectives::datatypes::BlockPartition;
use circulant_collectives::ops::SumOp;
use circulant_collectives::sim::{simulate, CostModel};
use circulant_collectives::topology::skips::{max_send_run, SkipScheme};
use circulant_collectives::util::rng::SplitMix64;
use circulant_collectives::util::stats::Summary;
use circulant_collectives::util::table::{fmt_si, Table};

fn main() {
    bench_header("T3", "Corollary 2 — skip schemes (rounds, runs, cost, wall-clock)");
    let ps: Vec<usize> = if fast_mode() { vec![22] } else { vec![22, 100, 1000, 4096] };
    let m_per_p = 256usize; // elements per block
    let schemes =
        [SkipScheme::HalvingUp, SkipScheme::PowerOfTwo, SkipScheme::Sqrt, SkipScheme::FullyConnected];
    let regimes = [
        ("latency", CostModel::latency_bound()),
        ("cluster", CostModel::cluster()),
        ("bandwidth", CostModel::bandwidth_bound()),
    ];

    for &p in &ps {
        let part = BlockPartition::uniform(p, m_per_p);
        let mut t = Table::new(
            &format!("T3: p={p}, {} f32/block", m_per_p),
            &["scheme", "rounds", "blocks/rank", "max run", "T(latency)", "T(cluster)", "T(bandwidth)", "wall (p≤22)"],
        );
        for scheme in &schemes {
            let skips = match scheme.skips(p) {
                Ok(s) => s,
                Err(e) => {
                    println!("  {}: {e}", scheme.name());
                    continue;
                }
            };
            let sched = reduce_scatter_schedule(p, &skips);
            sched.assert_valid();
            let counters = sched.counters(&part);
            assert_eq!(counters[0].blocks_sent, p - 1, "volume must be scheme-independent");
            let mut cells = vec![
                scheme.name(),
                skips.len().to_string(),
                counters[0].blocks_sent.to_string(),
                max_send_run(p, &skips).to_string(),
            ];
            for (_, model) in &regimes {
                cells.push(fmt_si(simulate(&sched, &part, model).total));
            }
            // Threaded wall-clock only at the small p (1-core box).
            if p <= 22 {
                let mut rng = SplitMix64::new(3);
                let inputs: Vec<Vec<f32>> =
                    (0..p).map(|_| rng.normal_vec(part.total())).collect();
                let sched2 = sched.clone();
                let part2 = part.clone();
                let samples = time_reps(1, if fast_mode() { 3 } else { 7 }, || {
                    let _ = run_schedule_threads(
                        &sched2,
                        &part2,
                        Arc::new(SumOp),
                        inputs.clone(),
                    );
                });
                cells.push(format!("{}s", fmt_si(Summary::of(&samples).median)));
            } else {
                cells.push("—".into());
            }
            t.row(&cells);
        }
        t.print();
    }
    println!("reading: round counts are the only differentiator (volume identical);");
    println!("halving-up = power-of-two = ⌈log2 p⌉ rounds, sqrt ≈ Θ(√p), full = p−1.");
    println!("halving-up's max run ≤ ⌈p/2⌉ enables the copy-halving of [22] (§3).");
}
