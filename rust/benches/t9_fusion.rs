//! T9 — fusion tier: fused small-op batching vs the unfused engine.
//!
//! The ISSUE-5 acceptance gate: for small (≤ 1 KiB) repeated allreduces
//! at p=8 under a windowed trace replay, the engine's fusion tier
//! (compatible in-flight ops coalesced into one circulant run) must
//! deliver ≥ 2× the ops/s of the same engine with fusion off. N small
//! allreduces as N separate schedules pay `N·⌈log₂ p⌉` round latencies;
//! fused they pay ~`⌈log₂ p⌉` per batch plus a pack/scatter copy that is
//! trivially cheap at these sizes. Every replayed op is verified against
//! the scalar oracle on both paths. Emits `BENCH_t9.json`.

use std::collections::VecDeque;
use std::time::Instant;

use circulant_collectives::bench_harness::{bench_header, fast_mode, BenchReport};
use circulant_collectives::engine::{CollectiveEngine, EngineConfig, OpRequest};
use circulant_collectives::util::stats::Summary;
use circulant_collectives::util::table::{fmt_si, Table};

/// Replay `n_ops` identical sum-allreduces through `engine` with a
/// window of in-flight operations (the serving pattern: submit ahead,
/// wait on the oldest), verifying every completed op. Returns ops/s.
fn replay(
    engine: &mut CollectiveEngine<f32>,
    inputs: &[Vec<f32>],
    want: &[f32],
    n_ops: usize,
    window: usize,
) -> f64 {
    let mut pending: VecDeque<_> = VecDeque::with_capacity(window);
    let t0 = Instant::now();
    for _ in 0..n_ops {
        pending.push_back(engine.submit(OpRequest::allreduce(inputs.to_vec(), "sum")).unwrap());
        if pending.len() >= window {
            let out = pending.pop_front().unwrap().wait().unwrap();
            assert_eq!(out[0], want, "fused/unfused replay produced a wrong sum");
        }
    }
    while let Some(h) = pending.pop_front() {
        let out = h.wait().unwrap();
        assert_eq!(out[0], want);
    }
    t0.elapsed().as_secs_f64().recip() * n_ops as f64
}

fn main() {
    bench_header("T9", "fusion tier — fused small-op batching vs unfused engine ops/s");
    let p = 8usize;
    let replay_window = 32usize;
    let fusion_window = 16u64;
    let fusion_max_bytes = 1 << 20;
    // ≤ 256 f32 elements = ≤ 1 KiB payloads: the latency-bound regime the
    // fusion tier exists for.
    let sizes: Vec<usize> = if fast_mode() { vec![64, 256] } else { vec![16, 64, 256] };
    let (reps, n_ops): (usize, usize) = if fast_mode() { (3, 400) } else { (5, 2000) };

    let mut report = BenchReport::new("t9");
    report.num("p", p as f64);
    report.num("replay_window", replay_window as f64);
    report.num("fusion_window", fusion_window as f64);
    report.num("fusion_max_bytes", fusion_max_bytes as f64);
    report.num("ops_per_replay", n_ops as f64);
    report.nums("sweep_m", sizes.iter().map(|&m| m as f64));

    let mut unfused_rates = Vec::new();
    let mut fused_rates = Vec::new();
    let mut speedups = Vec::new();
    let mut avg_batches = Vec::new();

    let mut t = Table::new(
        &format!("windowed replay of f32 sum-allreduces, p={p} (median of {reps} reps)"),
        &["m (elems)", "bytes", "unfused ops/s", "fused ops/s", "speedup", "avg batch"],
    );

    for &m in &sizes {
        let inputs: Vec<Vec<f32>> =
            (0..p).map(|r| (0..m).map(|j| ((r + j) % 7) as f32).collect()).collect();
        let want: Vec<f32> =
            (0..m).map(|j| (0..p).map(|r| ((r + j) % 7) as f32).sum()).collect();

        // --- unfused: the PR-4 engine as-is ---------------------------
        let mut engine: CollectiveEngine<f32> = CollectiveEngine::new(EngineConfig::new(p));
        replay(&mut engine, &inputs, &want, n_ops / 4, replay_window); // warm-up
        let unfused = Summary::of(
            &(0..reps)
                .map(|_| replay(&mut engine, &inputs, &want, n_ops, replay_window))
                .collect::<Vec<_>>(),
        );
        engine.shutdown();

        // --- fused: same engine + the fusion tier ---------------------
        let mut engine: CollectiveEngine<f32> = CollectiveEngine::new(
            EngineConfig::new(p)
                .fusion(true)
                .fusion_window(fusion_window)
                .fusion_max_bytes(fusion_max_bytes),
        );
        replay(&mut engine, &inputs, &want, n_ops / 4, replay_window); // warm-up
        let fused = Summary::of(
            &(0..reps)
                .map(|_| replay(&mut engine, &inputs, &want, n_ops, replay_window))
                .collect::<Vec<_>>(),
        );
        let fstats = engine.fusion_stats();
        engine.shutdown();
        assert!(fstats.batches > 0, "m={m}: the fused replay never formed a batch");
        assert!(
            fstats.avg_batch() >= 2.0,
            "m={m}: avg batch {:.2} < 2 — fusion is not coalescing",
            fstats.avg_batch()
        );

        let speedup = fused.median / unfused.median;
        t.row(&[
            m.to_string(),
            (4 * m).to_string(),
            fmt_si(unfused.median),
            fmt_si(fused.median),
            format!("{speedup:.1}×"),
            format!("{:.1}", fstats.avg_batch()),
        ]);
        unfused_rates.push(unfused.median);
        fused_rates.push(fused.median);
        speedups.push(speedup);
        avg_batches.push(fstats.avg_batch());

        // The acceptance gate (per size, all ≤ 1 KiB): fused ≥ 2× ops/s.
        assert!(
            speedup >= 2.0,
            "m={m} ({} B): fusion only {speedup:.2}× the unfused ops/s \
             ({} vs {}) — acceptance requires ≥ 2×",
            4 * m,
            fmt_si(fused.median),
            fmt_si(unfused.median),
        );
    }
    t.print();
    let min_speedup = speedups.iter().cloned().fold(f64::INFINITY, f64::min);
    println!(
        "fusion tier: fused batching beats the unfused engine by ≥ {min_speedup:.1}× ops/s \
         for every payload ≤ 1 KiB at p={p} under a windowed replay — message aggregation \
         over one round-optimal circulant run REPRODUCED"
    );
    report.nums("unfused_ops_per_sec", unfused_rates);
    report.nums("fused_ops_per_sec", fused_rates);
    report.nums("speedup", speedups);
    report.nums("avg_batch", avg_batches);
    report.num("min_speedup", min_speedup);
    report.num("gate_speedup", 2.0);
    report.write();
}
