//! T7 (extension) — answering the paper's open question with search.
//!
//! §2.1 leaves open which skip sequence performs best on a concrete
//! system. We search the full Corollary-2-valid space (exhaustive for
//! small p, beam for large) against two concrete machine models:
//!
//!   * homogeneous α-β-γ — expectation: every ⌈log2 p⌉-round sequence
//!     ties (round count is the only degree of freedom), so halving-up is
//!     already optimal; the search must confirm, not beat it.
//!   * clustered model with per-node NIC contention (`sim::hier`) —
//!     expectation: sequences whose large skips are multiples of the node
//!     size keep early (big) transfers on cheap intra-node edges, beating
//!     halving-up.

use circulant_collectives::bench_harness::{bench_header, fast_mode};
use circulant_collectives::collectives::reduce_scatter_schedule;
use circulant_collectives::datatypes::BlockPartition;
use circulant_collectives::sim::hier::{simulate_hier, HierModel};
use circulant_collectives::sim::{simulate, CostModel};
use circulant_collectives::topology::search::{beam_search, exhaustive_best};
use circulant_collectives::topology::skips::SkipScheme;
use circulant_collectives::util::table::{fmt_si, Table};

fn main() {
    bench_header("T7", "skip-sequence search (the §2.1 open question)");
    let m_per_p = 4096usize;

    // --- homogeneous model: search confirms halving-up ------------------
    let p = 22;
    let part = BlockPartition::uniform(p, m_per_p);
    let model = CostModel::cluster();
    let halving = SkipScheme::HalvingUp.skips(p).unwrap();
    let t_halving =
        simulate(&reduce_scatter_schedule(p, &halving), &part, &model).total;
    let (best_seq, t_best, visited) = exhaustive_best(p, |seq| {
        simulate(&reduce_scatter_schedule(p, &seq.to_vec()), &part, &model).total
    });
    println!("homogeneous, p={p} ({visited} valid sequences searched exhaustively):");
    println!("  halving-up {halving:?}: {}s", fmt_si(t_halving));
    println!("  search best {best_seq:?}: {}s", fmt_si(t_best));
    assert!(
        t_best >= t_halving * 0.999,
        "search should not beat halving-up homogeneously: {t_best} vs {t_halving}"
    );
    println!("  ⇒ halving-up already optimal in the homogeneous model ✓\n");

    // --- clustered contention model: node-aware sequences win -----------
    let p = 32;
    let node = 8;
    let hmodel = HierModel::typical(node);
    let part = BlockPartition::uniform(p, m_per_p);
    let eval = |seq: &[usize]| {
        simulate_hier(&reduce_scatter_schedule(p, &seq.to_vec()), &part, &hmodel).total
    };
    let halving = SkipScheme::HalvingUp.skips(p).unwrap();
    let t_halving = eval(&halving);
    let beam = if fast_mode() { 16 } else { 64 };
    let (best_seq, t_best) = beam_search(p, beam, eval);
    let mut t = Table::new(
        &format!("T7: clustered p={p}, node={node}, {m_per_p} f32/block"),
        &["sequence", "rounds", "time", "vs halving-up"],
    );
    t.row(&[
        format!("halving-up {halving:?}"),
        halving.len().to_string(),
        format!("{}s", fmt_si(t_halving)),
        "1.00×".into(),
    ]);
    t.row(&[
        format!("search {best_seq:?}"),
        best_seq.len().to_string(),
        format!("{}s", fmt_si(t_best)),
        format!("{:.2}×", t_halving / t_best),
    ]);
    // hand-crafted node-aware candidate: descend by node multiples first
    let node_aware: Vec<usize> = vec![16, 8, 4, 2, 1];
    let t_aware = eval(&node_aware);
    t.row(&[
        format!("pow2 {node_aware:?}"),
        node_aware.len().to_string(),
        format!("{}s", fmt_si(t_aware)),
        format!("{:.2}×", t_halving / t_aware),
    ]);
    t.print();
    assert!(t_best <= t_halving * 1.0001, "search must not lose to halving-up");
    println!(
        "⇒ on the clustered model the search finds a sequence ≥{:.2}× halving-up;",
        t_halving / t_best
    );
    println!("  the paper's open question has machine-dependent answers — this is the tool.");
}
