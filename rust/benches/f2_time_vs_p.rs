//! F2 — allreduce time vs processor count p.
//!
//! Fixed m, sweeping p (including non-powers of two — the paper's uniform-p
//! claim). Shape claims reproduced:
//!   * ring degrades linearly in p through the 2(p−1)·α term;
//!   * Algorithm 2 stays logarithmic in the α term with volume → 2m;
//!   * Algorithm 2 has no power-of-two cliffs, while recursive
//!     doubling/Rabenseifner pay fold rounds at p ≠ 2^k (visible as a jump
//!     between p=2^k and p=2^k+1).

use circulant_collectives::bench_harness::{bench_header, fast_mode};
use circulant_collectives::collectives::Algorithm;
use circulant_collectives::datatypes::BlockPartition;
use circulant_collectives::sim::{simulate, CostModel};
use circulant_collectives::util::table::{fmt_si, Table};

fn main() {
    bench_header("F2", "allreduce time vs p (DES, α-β-γ cluster model)");
    let model = CostModel::cluster();
    let ms: Vec<usize> = if fast_mode() { vec![1 << 10] } else { vec![1 << 10, 1 << 20] };
    let ps: Vec<usize> = if fast_mode() {
        vec![2, 16, 17, 64, 65]
    } else {
        vec![2, 3, 4, 8, 9, 16, 17, 32, 33, 64, 65, 128, 129, 256, 257, 512, 513, 1024, 1025, 4096, 4097]
    };

    for &m in &ms {
        let algs = Algorithm::allreduce_family();
        let mut header: Vec<String> = vec!["p".into()];
        header.extend(algs.iter().map(|a| a.name()));
        let hrefs: Vec<&str> = header.iter().map(String::as_str).collect();
        let mut t = Table::new(&format!("F2: time vs p, m={} (seconds)", fmt_si(m as f64)), &hrefs);
        for &p in &ps {
            let part = BlockPartition::regular(p, m);
            let mut cells = vec![p.to_string()];
            for alg in &algs {
                let sim = simulate(&alg.schedule(p), &part, &model);
                cells.push(fmt_si(sim.total));
            }
            t.row(&cells);
        }
        t.print();
    }

    // Shape assertions.
    let m = 1 << 10;
    let sim_at = |alg: &Algorithm, p: usize| {
        simulate(&alg.schedule(p), &BlockPartition::regular(p, m), &model).total
    };
    let circ = Algorithm::parse("allreduce").unwrap();
    // logarithmic vs linear scaling: going 64 → 1024 (16×) multiplies ring
    // cost by ~≥8 but Algorithm 2's by a small factor.
    let ring_ratio = sim_at(&Algorithm::RingAllreduce, 1024) / sim_at(&Algorithm::RingAllreduce, 64);
    let circ_ratio = sim_at(&circ, 1024) / sim_at(&circ, 64);
    assert!(ring_ratio > 8.0, "ring should scale ~linearly, got ×{ring_ratio:.1}");
    assert!(circ_ratio < 3.0, "Alg 2 should scale ~logarithmically, got ×{circ_ratio:.1}");
    // no power-of-two cliff for Alg 2; a visible one for recursive doubling
    let cliff = |alg: &Algorithm| sim_at(alg, 129) / sim_at(alg, 128);
    assert!(cliff(&circ) < 1.25, "Alg 2 cliff {:.2}", cliff(&circ));
    assert!(
        cliff(&Algorithm::RecursiveDoublingAllreduce) > cliff(&circ),
        "rec-doubling should pay a fold penalty at 129"
    );
    println!("shape checks ✓ (ring linear, Alg 2 logarithmic, no 2^k cliffs for Alg 2)");
}
