//! F1 — allreduce time vs vector length m (the crossover figure).
//!
//! DES evaluation of every allreduce algorithm plus analytic pipelined /
//! two-tree estimates, at fixed p, sweeping m over powers of two. The
//! *shape* claims being reproduced (paper §1/§2.2):
//!   * small m: ⌈log2 p⌉-round algorithms (recursive doubling, binomial)
//!     win on the α term; ring is worst by ~p/log p;
//!   * large m: volume-optimal algorithms win; Algorithm 2 and ring tie on
//!     volume but Algorithm 2 keeps the log α term, so it tracks the
//!     lower envelope at both ends;
//!   * the crossover m* between rec-doubling and Algorithm 2 scales like
//!     α·log p/β.

use circulant_collectives::bench_harness::{bench_header, fast_mode};
use circulant_collectives::collectives::Algorithm;
use circulant_collectives::datatypes::BlockPartition;
use circulant_collectives::sim::{closed_form, simulate, CostModel};
use circulant_collectives::util::table::{fmt_si, Table};

fn main() {
    bench_header("F1", "allreduce time vs m (DES, α-β-γ cluster model)");
    let model = CostModel::cluster();
    let ps: Vec<usize> = if fast_mode() { vec![64] } else { vec![64, 1000] };
    let m_range: Vec<usize> = (4..=if fast_mode() { 16 } else { 24 }).map(|e| 1usize << e).collect();

    for &p in &ps {
        let algs = Algorithm::allreduce_family();
        let mut header: Vec<String> = vec!["m".into()];
        header.extend(algs.iter().map(|a| a.name()));
        header.push("pipelined-tree".into());
        header.push("two-tree".into());
        header.push("winner".into());
        let hrefs: Vec<&str> = header.iter().map(String::as_str).collect();
        let mut t = Table::new(&format!("F1: time vs m, p={p} (seconds)"), &hrefs);

        let mut crossover: Option<usize> = None;
        let mut prev_winner = String::new();
        for &m in &m_range {
            let part = BlockPartition::regular(p, m);
            let mut cells = vec![fmt_si(m as f64)];
            let mut best = ("", f64::INFINITY);
            let mut times = Vec::new();
            for alg in &algs {
                let sched = alg.schedule(p);
                let sim = simulate(&sched, &part, &model);
                times.push(sim.total);
                cells.push(fmt_si(sim.total));
            }
            for (alg, tt) in algs.iter().zip(&times) {
                if *tt < best.1 {
                    best = (Box::leak(alg.name().into_boxed_str()), *tt);
                }
            }
            let pt = closed_form::pipelined_binary_tree_allreduce(&model, p, m);
            let tt = closed_form::two_tree_allreduce(&model, p, m);
            cells.push(fmt_si(pt));
            cells.push(fmt_si(tt));
            if pt < best.1 {
                best = ("pipelined-tree", pt);
            }
            if tt < best.1 {
                best = ("two-tree", tt);
            }
            cells.push(best.0.to_string());
            if !prev_winner.is_empty() && prev_winner != best.0 && crossover.is_none() {
                crossover = Some(m);
            }
            prev_winner = best.0.to_string();
            t.row(&cells);
        }
        t.print();
        if let Some(m) = crossover {
            println!("first winner change at m ≈ {} (expected scale α·log2 p/β ≈ {})\n",
                fmt_si(m as f64),
                fmt_si(model.alpha * (p as f64).log2() / model.beta));
        }

        // Shape assertions (the reproduction criteria):
        let small = BlockPartition::regular(p, 16);
        let large = BlockPartition::regular(p, 1 << 24);
        let sim_at = |alg: &Algorithm, part: &BlockPartition| {
            simulate(&alg.schedule(p), part, &model).total
        };
        let circ = Algorithm::parse("allreduce").unwrap();
        let ring = Algorithm::RingAllreduce;
        let rd = Algorithm::RecursiveDoublingAllreduce;
        // ring is far worse for small m
        assert!(sim_at(&circ, &small) < sim_at(&ring, &small) / 4.0, "p={p} small-m shape");
        // Alg 2 within 1% of ring (volume twins) and beats rec-doubling for large m
        assert!(sim_at(&circ, &large) <= sim_at(&ring, &large) * 1.01, "p={p} large-m vs ring");
        assert!(sim_at(&circ, &large) < sim_at(&rd, &large), "p={p} large-m vs rec-doubling");
    }
    println!("shape checks ✓ (log-round wins small m; volume-optimal wins large m; Alg 2 tracks both)");
}
