//! Engine concurrency suite (ISSUE-4): N in-flight operations on ONE
//! persistent engine must be bit-identical (exact integer dtypes) to the
//! same operations run sequentially, across both copy tiers — and the
//! engine must never spawn threads per operation.
//!
//! CI runs this suite twice: as-is (rendezvous tier active where
//! schedules allow) and under `CCOLL_NO_RENDEZVOUS=1` (pooled tier only),
//! so both tiers are covered in both engine configurations exercised
//! below.

use std::sync::{Mutex, MutexGuard};

use circulant_collectives::cli::main_with_args;
use circulant_collectives::datatypes::{elem, Elem};
use circulant_collectives::engine::{
    CollectiveEngine, CollectiveKind, EngineConfig, EngineError, OpRequest,
};
use circulant_collectives::ops::ReduceOp;
use circulant_collectives::ops::SumOp;
use circulant_collectives::topology::skips::SkipScheme;
use circulant_collectives::transport::rank_threads_spawned;
use circulant_collectives::util::rng::SplitMix64;

/// Serialize every test in this binary: some assert on the process-global
/// rank-thread-spawn counter (`ccoll serve` does so internally), which a
/// concurrently running engine test would pollute.
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

fn int_inputs<T: Elem>(p: usize, m: usize, seed: u64) -> Vec<Vec<T>> {
    let (lo, hi) = elem::test_value_bounds(T::DTYPE);
    let mut rng = SplitMix64::new(seed);
    (0..p).map(|_| elem::int_vec(&mut rng, m, lo, hi)).collect()
}

/// A deterministic mixed workload: allreduces and reduce-scatters over
/// several sizes and ops, reproducible per seed.
fn mixed_requests<T: Elem>(p: usize, n: usize, seed: u64) -> Vec<OpRequest<T>> {
    let sizes = [4 * p + 3, 16, 2 * p, 64];
    let mut rng = SplitMix64::new(seed);
    (0..n)
        .map(|i| {
            let m = sizes[rng.next_below(sizes.len())];
            let inputs = int_inputs::<T>(p, m, seed.wrapping_mul(31).wrapping_add(i as u64));
            let op = if i % 3 == 0 { "max" } else { "sum" };
            match i % 2 {
                0 => OpRequest::allreduce(inputs, op),
                _ => OpRequest::reduce_scatter(inputs, op),
            }
        })
        .collect()
}

fn engine_with_tier<T: Elem>(p: usize, rendezvous: bool) -> CollectiveEngine<T> {
    // Pin the rendezvous threshold to 0 so the zero-copy tier engages
    // deterministically for every payload size when enabled (mirrors the
    // executor test drivers).
    CollectiveEngine::new(
        EngineConfig::new(p).rendezvous(rendezvous).rendezvous_min_elems(0),
    )
}

/// Run the same request list sequentially (submit → wait, one at a time)
/// and return the per-op per-rank results.
fn run_sequential<T: Elem>(p: usize, reqs: Vec<OpRequest<T>>, rendezvous: bool) -> Vec<Vec<Vec<T>>> {
    let mut engine = engine_with_tier::<T>(p, rendezvous);
    let out = reqs
        .into_iter()
        .map(|req| engine.submit(req).unwrap().wait().unwrap())
        .collect();
    engine.shutdown();
    out
}

/// Submit ALL requests before waiting on any, then wait in reverse
/// submission order — maximal overlap plus out-of-order joins.
fn run_concurrent<T: Elem>(p: usize, reqs: Vec<OpRequest<T>>, rendezvous: bool) -> Vec<Vec<Vec<T>>> {
    let mut engine = engine_with_tier::<T>(p, rendezvous);
    let handles: Vec<_> = reqs.into_iter().map(|req| engine.submit(req).unwrap()).collect();
    let n = handles.len();
    let mut out: Vec<Option<Vec<Vec<T>>>> = (0..n).map(|_| None).collect();
    for (i, handle) in handles.into_iter().enumerate().rev() {
        out[i] = Some(handle.wait().unwrap());
    }
    engine.shutdown();
    out.into_iter().map(|r| r.unwrap()).collect()
}

#[test]
fn concurrent_ops_bit_identical_to_sequential_i64() {
    let _serial = serial();
    // Exact wrapping arithmetic: any divergence (cross-matched payload,
    // wrong schedule, reordered ⊕) shows up as a bit difference.
    for p in [2usize, 5, 8] {
        for rendezvous in [true, false] {
            let seq = run_sequential::<i64>(p, mixed_requests(p, 12, 99 + p as u64), rendezvous);
            let conc = run_concurrent::<i64>(p, mixed_requests(p, 12, 99 + p as u64), rendezvous);
            assert_eq!(
                seq, conc,
                "p={p} rendezvous={rendezvous}: concurrent ≠ sequential (bit-exact i64)"
            );
        }
    }
}

#[test]
fn concurrent_ops_bit_identical_to_sequential_u64() {
    let _serial = serial();
    for rendezvous in [true, false] {
        let p = 5;
        let seq = run_sequential::<u64>(p, mixed_requests(p, 10, 7), rendezvous);
        let conc = run_concurrent::<u64>(p, mixed_requests(p, 10, 7), rendezvous);
        assert_eq!(seq, conc, "rendezvous={rendezvous}: u64 mix diverged");
    }
}

#[test]
fn concurrent_results_match_scalar_oracle_i64() {
    let _serial = serial();
    // Independent ground truth (not just self-consistency): every
    // in-flight allreduce must equal the wrapping scalar fold of its own
    // inputs — concurrent ops must not bleed into each other.
    let p = 4;
    let n = 8;
    let mut engine = engine_with_tier::<i64>(p, true);
    let mut handles = Vec::new();
    let mut oracles = Vec::new();
    for i in 0..n {
        let m = 11 + 7 * i; // every op a different size
        let inputs = int_inputs::<i64>(p, m, 1000 + i as u64);
        let mut want = vec![0i64; m];
        for v in &inputs {
            SumOp.combine(&mut want, v);
        }
        oracles.push(want);
        handles.push(engine.submit(OpRequest::allreduce(inputs, "sum")).unwrap());
    }
    for (i, handle) in handles.into_iter().enumerate().rev() {
        let out = handle.wait().unwrap();
        for (r, buf) in out.iter().enumerate() {
            assert_eq!(buf, &oracles[i], "op {i} rank {r}");
        }
    }
    engine.shutdown();
}

#[test]
fn irregular_reduce_scatter_counts_through_the_engine() {
    let _serial = serial();
    let p = 4;
    let counts = vec![1usize, 0, 5, 2];
    let m: usize = counts.iter().sum();
    let inputs = int_inputs::<i64>(p, m, 42);
    let mut want = vec![0i64; m];
    for v in &inputs {
        SumOp.combine(&mut want, v);
    }
    let part = circulant_collectives::datatypes::BlockPartition::from_counts(&counts);
    let mut engine = engine_with_tier::<i64>(p, true);
    let out = engine
        .submit(OpRequest::reduce_scatter_counts(inputs, counts, "sum"))
        .unwrap()
        .wait()
        .unwrap();
    for (r, buf) in out.iter().enumerate() {
        assert_eq!(&buf[part.range(r)], &want[part.range(r)], "rank {r}");
    }
    engine.shutdown();
}

#[test]
fn queue_depth_bounds_in_flight_ops() {
    let _serial = serial();
    let p = 3;
    let depth = 2;
    let mut engine: CollectiveEngine<i64> =
        CollectiveEngine::new(EngineConfig::new(p).queue_depth(depth));
    let mut handles = Vec::new();
    for i in 0..10 {
        let handle = engine.submit(OpRequest::allreduce(int_inputs(p, 32, i), "sum")).unwrap();
        assert!(
            engine.in_flight() <= depth,
            "after submit {i}: {} in flight > depth {depth}",
            engine.in_flight()
        );
        handles.push(handle);
    }
    for handle in handles {
        handle.wait().unwrap();
    }
    // The last rank's slot release races the final wait() return by a few
    // instructions; give it a bounded moment before asserting drain.
    for _ in 0..10_000 {
        if engine.in_flight() == 0 {
            break;
        }
        std::thread::yield_now();
    }
    assert_eq!(engine.in_flight(), 0);
    engine.shutdown();
}

#[test]
fn engine_reuses_workers_across_many_ops() {
    let _serial = serial();
    // Mini-soak: hundreds of mixed ops through one engine, then prove the
    // spawn-once property with the process-wide rank-thread counter.
    let p = 4;
    let before = rank_threads_spawned();
    let mut engine = engine_with_tier::<i64>(p, true);
    let reqs = mixed_requests::<i64>(p, 300, 5);
    let mut window = std::collections::VecDeque::new();
    for req in reqs {
        window.push_back(engine.submit(req).unwrap());
        if window.len() >= 8 {
            window.pop_front().unwrap().wait().unwrap();
        }
    }
    while let Some(h) = window.pop_front() {
        h.wait().unwrap();
    }
    let stats = engine.plan_stats();
    engine.shutdown();
    let spawned = rank_threads_spawned() - before;
    assert_eq!(spawned, p as u64, "engine must spawn exactly p workers for 300 ops");
    // 4 sizes × 2 kinds = at most 8 distinct plans for 300 ops.
    assert!(stats.entries <= 8, "{} plans cached", stats.entries);
    assert!(stats.hits >= 292, "only {} plan hits over 300 ops", stats.hits);
}

#[test]
fn out_of_order_completion_small_overtakes_large() {
    let _serial = serial();
    // A large op submitted first and a tiny op submitted second: waiting
    // on the tiny one first must complete promptly (the worker loop
    // interleaves, so the small op cannot be queued behind the large
    // one). Correctness of both is asserted; timing is not (CI boxes).
    let p = 4;
    let mut engine = engine_with_tier::<i64>(p, true);
    let big_inputs = int_inputs::<i64>(p, 200_000, 1);
    let mut big_want = vec![0i64; 200_000];
    for v in &big_inputs {
        SumOp.combine(&mut big_want, v);
    }
    let small_inputs = int_inputs::<i64>(p, 16, 2);
    let mut small_want = vec![0i64; 16];
    for v in &small_inputs {
        SumOp.combine(&mut small_want, v);
    }
    let big = engine.submit(OpRequest::allreduce(big_inputs, "sum")).unwrap();
    let small = engine.submit(OpRequest::allreduce(small_inputs, "sum")).unwrap();
    assert!(small.op_id() > big.op_id(), "submission order gives monotone epochs");
    let small_out = small.wait().unwrap();
    for buf in &small_out {
        assert_eq!(buf, &small_want);
    }
    let big_out = big.wait().unwrap();
    for buf in &big_out {
        assert_eq!(buf, &big_want);
    }
    engine.shutdown();
}

#[test]
fn engine_matches_launcher_results_f32() {
    let _serial = serial();
    // Cross-entry-point agreement in f32 (small-integer values keep IEEE
    // sums exact): the engine and the one-shot launcher must produce the
    // same bits for the same inputs and schedule.
    use circulant_collectives::coordinator::Launcher;
    let p = 5;
    let m = 33;
    let inputs = int_inputs::<f32>(p, m, 321);
    let mut engine: CollectiveEngine<f32> =
        CollectiveEngine::new(EngineConfig::new(p).scheme(SkipScheme::HalvingUp));
    let engine_out =
        engine.submit(OpRequest::allreduce(inputs.clone(), "sum")).unwrap().wait().unwrap();
    engine.shutdown();
    let inputs2 = std::sync::Arc::new(std::sync::Mutex::new(
        inputs.into_iter().map(Some).collect::<Vec<_>>(),
    ));
    let launcher_out = Launcher::new(p).run(move |mut comm| {
        let mut buf = inputs2.lock().unwrap()[comm.rank()].take().unwrap();
        comm.allreduce(&mut buf, "sum").unwrap();
        buf
    });
    assert_eq!(engine_out, launcher_out);
}

#[test]
fn kind_debug_and_errors_render() {
    let _serial = serial();
    // EngineError surfaces readable diagnostics (the CLI prints them).
    let mut engine = CollectiveEngine::<i64>::new(EngineConfig::new(2));
    let err = engine.submit(OpRequest::allreduce(int_inputs(3, 4, 0), "sum")).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("inputs for 3 ranks"), "{msg}");
    let err = engine
        .submit(OpRequest {
            kind: CollectiveKind::ReduceScatterCounts(vec![9, 9]),
            op: "sum".into(),
            inputs: int_inputs(2, 4, 0),
        })
        .unwrap_err();
    assert!(matches!(err, EngineError::BadCounts { got: 4, want: 18 }), "{err}");
    engine.shutdown();
}

// ---------------------------------------------------------------------
// `ccoll serve` — the replay driver end-to-end (in-process CLI calls).
// ---------------------------------------------------------------------

fn args(s: &[&str]) -> Vec<String> {
    s.iter().map(|x| x.to_string()).collect()
}

#[test]
fn serve_replays_a_synthetic_mix() {
    let _serial = serial();
    main_with_args(args(&[
        "serve",
        "--serve.p",
        "4",
        "--serve.ops",
        "60",
        "--serve.m",
        "128",
        "--serve.inflight",
        "6",
    ]))
    .unwrap();
}

#[test]
fn serve_replays_a_recorded_trace_in_i64() {
    let _serial = serial();
    let dir = std::env::temp_dir();
    let path = dir.join(format!("ccoll_trace_{}.txt", std::process::id()));
    std::fs::write(
        &path,
        "# recorded mix\nallreduce 64 sum\nrs 33 sum\nar 128 max\nreduce-scatter 16 sum\n",
    )
    .unwrap();
    main_with_args(args(&[
        "serve",
        "--serve.p",
        "3",
        "--trace",
        path.to_str().unwrap(),
        "--run.dtype",
        "i64",
    ]))
    .unwrap();
    std::fs::remove_file(&path).ok();
}

#[test]
fn serve_rejects_bad_traces_and_knobs() {
    let _serial = serial();
    let dir = std::env::temp_dir();
    let path = dir.join(format!("ccoll_bad_trace_{}.txt", std::process::id()));
    std::fs::write(&path, "frobnicate 64 sum\n").unwrap();
    let err = main_with_args(args(&["serve", "--trace", path.to_str().unwrap()])).unwrap_err();
    assert!(err.to_string().contains("unknown kind"), "{err}");
    std::fs::remove_file(&path).ok();
    let err = main_with_args(args(&[
        "serve",
        "--serve.p",
        "2",
        "--serve.ops",
        "2",
        "--engine.park",
        "nap",
    ]))
    .unwrap_err();
    assert!(err.to_string().contains("spin|yield|sleep"), "{err}");
}
