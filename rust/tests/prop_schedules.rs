//! Property tests over randomly generated schedules and partitions.
//!
//! proptest is unavailable offline; these are seeded-PRNG property sweeps
//! (hundreds of random cases per property, deterministic per seed) over:
//!   * random valid custom skip sequences — Corollary 2 in its full
//!     generality, not just the four named schemes;
//!   * random irregular partitions — Corollary 3;
//!   * the implication chain: in-place condition ⇒ distinct-sum
//!     completeness ⇒ symbolic correctness ⇒ counter optimality.

use circulant_collectives::analysis;
use circulant_collectives::collectives::{
    allreduce_schedule, reduce_scatter_schedule, Algorithm,
};
use circulant_collectives::datatypes::BlockPartition;
use circulant_collectives::schedule::Schedule;
use circulant_collectives::topology::skips::{is_complete, validate, SkipScheme};
use circulant_collectives::topology::SpanningTree;
use circulant_collectives::util::ceil_log2;
use circulant_collectives::util::rng::SplitMix64;

/// Generate a random *valid* skip sequence for p: start at p, repeatedly
/// pick the next skip uniformly from the valid window [⌈s/2⌉, s−1].
fn random_valid_skips(p: usize, rng: &mut SplitMix64) -> Vec<usize> {
    let mut v = Vec::new();
    let mut s = p;
    while s > 1 {
        let lo = s.div_ceil(2);
        let hi = s - 1;
        let next = lo + rng.next_below(hi - lo + 1);
        v.push(next);
        s = next;
    }
    v
}

#[test]
fn random_skip_sequences_satisfy_corollary2() {
    let mut rng = SplitMix64::new(0xC0_FFEE);
    for _ in 0..300 {
        let p = 2 + rng.next_below(200);
        let skips = random_valid_skips(p, &mut rng);
        validate(p, &skips).unwrap_or_else(|e| panic!("p={p} {skips:?}: {e}"));
        // in-place condition ⇒ every i decomposes into distinct skips
        assert!(is_complete(p, &skips), "p={p} {skips:?} not complete");
        // and the spanning forest is a correct proof object
        SpanningTree::build(p, &skips).invariant_checks().unwrap();
    }
}

#[test]
fn random_schedules_have_optimal_counters() {
    let mut rng = SplitMix64::new(42);
    for _ in 0..120 {
        let p = 2 + rng.next_below(100);
        let skips = random_valid_skips(p, &mut rng);
        let sched = reduce_scatter_schedule(p, &skips);
        sched.assert_valid();
        assert_eq!(sched.num_rounds(), skips.len());
        let part = BlockPartition::uniform(p, 1);
        for c in sched.counters(&part) {
            // Volume optimality holds for ANY valid sequence (Theorem 1's
            // proof never uses the halving structure).
            assert_eq!(c.blocks_sent, p - 1, "p={p} {skips:?}");
            assert_eq!(c.blocks_recv, p - 1);
            assert_eq!(c.blocks_combined, p - 1);
        }
    }
}

#[test]
fn random_schedules_symbolically_correct() {
    let mut rng = SplitMix64::new(7);
    for _ in 0..40 {
        let p = 2 + rng.next_below(48);
        let skips = random_valid_skips(p, &mut rng);
        let rs = reduce_scatter_schedule(p, &skips);
        analysis::verify_reduce_scatter(&rs)
            .unwrap_or_else(|e| panic!("p={p} {skips:?}: {e}"));
        let ar = allreduce_schedule(p, &skips);
        analysis::verify_allreduce(&ar).unwrap_or_else(|e| panic!("p={p} {skips:?}: {e}"));
    }
}

#[test]
fn counters_scale_exactly_with_irregular_partitions() {
    // elems_sent per rank must equal the sum over rounds of the block-range
    // sizes, whatever the partition — cross-check two independent code
    // paths (schedule counters vs spanning-tree accounting).
    let mut rng = SplitMix64::new(99);
    for _ in 0..60 {
        let p = 2 + rng.next_below(40);
        let m = 1 + rng.next_below(10_000);
        let part = BlockPartition::random(p, m, rng.next_u64());
        let skips = SkipScheme::HalvingUp.skips(p).unwrap();
        let sched = reduce_scatter_schedule(p, &skips);
        let counters = sched.counters(&part);
        // Every global block g ≠ r is sent exactly once by rank r (as the
        // partial destined for g): elems_sent = m − size((r)) … in R-space,
        // rank r sends blocks (r+1..r+p) mod p exactly once each.
        for (r, c) in counters.iter().enumerate() {
            let expect: usize =
                (1..p).map(|i| part.size((r + i) % p)).sum();
            assert_eq!(c.elems_sent, expect, "p={p} m={m} r={r}");
        }
    }
}

#[test]
fn halving_up_run_bound_is_tight_only_for_halving() {
    // §3 property as a property test: halving-up max run ≤ ⌈p/2⌉ for all p.
    for p in 2..600 {
        let skips = SkipScheme::HalvingUp.skips(p).unwrap();
        let sched = allreduce_schedule(p, &skips);
        assert!(sched.max_message_blocks() <= p.div_ceil(2), "p={p}");
    }
}

#[test]
fn all_algorithms_structurally_valid_random_p() {
    let mut rng = SplitMix64::new(1234);
    for _ in 0..50 {
        let p = 2 + rng.next_below(64);
        let algs: Vec<Algorithm> = vec![
            Algorithm::parse("rs").unwrap(),
            Algorithm::parse("ar").unwrap(),
            Algorithm::parse("ag").unwrap(),
            Algorithm::parse("rs:sqrt").unwrap(),
            Algorithm::parse("ar:pow2").unwrap(),
            Algorithm::RingReduceScatter,
            Algorithm::RingAllreduce,
            Algorithm::RecursiveDoublingAllreduce,
            Algorithm::RabenseifnerAllreduce,
            Algorithm::BinomialAllreduce,
            Algorithm::BruckAllgather,
            Algorithm::BinomialReduce { root: rng.next_below(p) },
            Algorithm::BinomialBcast { root: rng.next_below(p) },
        ];
        for alg in algs {
            let sched: Schedule = alg.schedule(p);
            sched.assert_valid();
        }
    }
}

#[test]
fn round_lower_bound_is_respected_and_achieved() {
    // No valid skip sequence can beat ⌈log2 p⌉ rounds (each round at most
    // doubles the set of inputs a partial can contain), and halving-up
    // achieves it.
    let mut rng = SplitMix64::new(55);
    for _ in 0..200 {
        let p = 2 + rng.next_below(500);
        let skips = random_valid_skips(p, &mut rng);
        assert!(skips.len() as u32 >= ceil_log2(p), "p={p} {skips:?} beats the lower bound?!");
        let halving = SkipScheme::HalvingUp.skips(p).unwrap();
        assert_eq!(halving.len() as u32, ceil_log2(p));
    }
}

#[test]
fn allreduce_equals_rs_plus_mirrored_ag_rounds() {
    let mut rng = SplitMix64::new(77);
    for _ in 0..100 {
        let p = 2 + rng.next_below(128);
        let skips = random_valid_skips(p, &mut rng);
        let ar = allreduce_schedule(p, &skips);
        assert_eq!(ar.num_rounds(), 2 * skips.len());
        let part = BlockPartition::uniform(p, 2);
        for c in ar.counters(&part) {
            assert_eq!(c.blocks_sent, 2 * (p - 1));
            assert_eq!(c.blocks_combined, p - 1);
        }
    }
}
