//! Cross-backend transport oracles (ISSUE 6): the thread backend (the
//! PR 1–5 oracle, with its rendezvous/pooled tiers) and the
//! Unix-domain-socket backend (framed copies only) execute the SAME
//! schedule over the SAME inputs — the schedule fixes the ⊕ association,
//! so for the wrapping-integer dtypes the two backends must produce
//! **bit-identical** results for every schedule generator in the library
//! and for regular and zipf partitions alike. No tolerances anywhere in
//! this file: every assertion is `==` on integer values.
//!
//! Also here: the UDS engine mini-soak — ≥100 operations through ONE
//! `CollectiveEngine::with_transports` over socket transports, asserting
//! exact results and spawn-once per process (the engine's `p` workers are
//! the only rank threads the whole soak creates).

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use circulant_collectives::collectives::{
    baselines, execute_rank, run_schedule_threads_tiered_typed, Algorithm,
};
use circulant_collectives::datatypes::elem::{int_vec, test_value_bounds};
use circulant_collectives::datatypes::{BlockPartition, Elem};
use circulant_collectives::engine::{CollectiveEngine, EngineConfig, OpRequest};
use circulant_collectives::ops::{ReduceOp, SumOp};
use circulant_collectives::schedule::Schedule;
use circulant_collectives::topology::skips::SkipScheme;
use circulant_collectives::transport::rank_threads_spawned;
use circulant_collectives::transport::uds::uds_network_typed;
use circulant_collectives::util::rng::SplitMix64;

/// Every test in this binary takes this guard: the mini-soak asserts an
/// exact `rank_threads_spawned` delta, and the identity tests spawn rank
/// threads of their own (the thread-backend side), so they must not
/// overlap with it.
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// A fresh scratch directory for one UDS mesh (sockets are filesystem
/// objects, so concurrent meshes need disjoint directories).
fn scratch_dir(tag: &str) -> PathBuf {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    let n = NEXT.fetch_add(1, Ordering::Relaxed);
    let dir = std::env::temp_dir()
        .join(format!("ccoll-xbackend-{tag}-{}-{n}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create scratch dir");
    dir
}

fn inputs_for<T: Elem>(p: usize, m: usize, seed: u64) -> Vec<Vec<T>> {
    let (lo, hi) = test_value_bounds(T::DTYPE);
    let mut rng = SplitMix64::new(seed);
    (0..p).map(|_| int_vec(&mut rng, m, lo, hi)).collect()
}

/// Scalar fold of `op` over all rank inputs — exact for integer dtypes in
/// any association, so it is THE unique correct answer.
fn fold_oracle<T: Elem>(inputs: &[Vec<T>], op: &dyn ReduceOp<T>) -> Vec<T> {
    let mut acc = vec![op.identity(); inputs[0].len()];
    for v in inputs {
        op.combine(&mut acc, v);
    }
    acc
}

/// Every schedule generator in the library, instantiated for `p` (rooted
/// generators at two roots; power-of-two-only generators gated) — the
/// same enumeration `rust/tests/dtype_oracles.rs` uses for its cross-tier
/// matrix.
fn all_generator_schedules(p: usize) -> Vec<Schedule> {
    let mut v = Vec::new();
    for scheme in [SkipScheme::HalvingUp, SkipScheme::PowerOfTwo, SkipScheme::Sqrt] {
        let skips = scheme.skips(p).unwrap();
        v.push(circulant_collectives::collectives::reduce_scatter_schedule(p, &skips));
        v.push(circulant_collectives::collectives::allgather_schedule(p, &skips));
        v.push(circulant_collectives::collectives::allreduce_schedule(p, &skips));
    }
    v.push(baselines::ring_reduce_scatter_schedule(p));
    v.push(baselines::ring_allgather_schedule(p));
    v.push(baselines::ring_allreduce_schedule(p));
    v.push(baselines::bruck_allgather_schedule(p));
    v.push(baselines::binomial_allreduce_schedule(p));
    v.push(baselines::rabenseifner_allreduce_schedule(p));
    v.push(baselines::recursive_doubling_allreduce_schedule(p));
    for root in [0, p - 1] {
        v.push(baselines::binomial_reduce_schedule(p, root));
        v.push(baselines::binomial_bcast_schedule(p, root));
        v.push(baselines::binomial_scatter_schedule(p, root));
        v.push(baselines::binomial_gather_schedule(p, root));
    }
    if p.is_power_of_two() {
        v.push(baselines::recursive_halving_rs_schedule(p));
        v.push(baselines::recursive_doubling_ag_schedule(p));
    }
    v
}

/// The partition shapes of the cross-backend matrix for one `(p, m)`:
/// the regular partition and a skewed zipf partition (possibly with
/// empty blocks — zero-length frames must round-trip the sockets too).
fn partitions(p: usize, m: usize) -> Vec<(&'static str, BlockPartition)> {
    vec![
        ("regular", BlockPartition::regular(p, m)),
        ("zipf", BlockPartition::zipf(p, m, 1.3, p as u64)),
    ]
}

/// Execute one schedule over a fresh p-process-shaped UDS mesh (p
/// transports in this process, one plain thread per rank — the wire is
/// real sockets even though the ranks share an address space here).
fn run_uds<T: Elem>(
    sched: &Schedule,
    part: &BlockPartition,
    inputs: &[Vec<T>],
    tag: &str,
) -> Vec<Vec<T>> {
    let p = sched.p;
    let dir = scratch_dir(tag);
    let transports = uds_network_typed::<T>(p, &dir).expect("uds bootstrap");
    let sched = Arc::new(sched.clone());
    let part = Arc::new(part.clone());
    let handles: Vec<_> = transports
        .into_iter()
        .enumerate()
        .map(|(r, mut t)| {
            let sched = sched.clone();
            let part = part.clone();
            let mut buf = inputs[r].clone();
            std::thread::Builder::new()
                .name(format!("uds-oracle-rank-{r}"))
                .stack_size(8 << 20)
                .spawn(move || {
                    execute_rank(&mut t, &sched, &part, &SumOp, &mut buf, 0)
                        .unwrap_or_else(|e| panic!("uds rank {r}: {e}"));
                    buf
                })
                .expect("spawn uds oracle rank")
        })
        .collect();
    let out = handles.into_iter().map(|h| h.join().expect("uds rank thread")).collect();
    let _ = std::fs::remove_dir_all(&dir);
    out
}

fn assert_cross_backend_identity<T: Elem>(seed: u64) {
    let _guard = serial();
    for p in [2usize, 5, 8] {
        let m = 7 * p + 3;
        for (wname, part) in partitions(p, m) {
            for sched in all_generator_schedules(p) {
                let inputs = inputs_for::<T>(p, part.total(), seed + p as u64);
                let thread = run_schedule_threads_tiered_typed::<T>(
                    &sched,
                    &part,
                    Arc::new(SumOp),
                    inputs.clone(),
                    true,
                );
                let uds = run_uds::<T>(&sched, &part, &inputs, "gen");
                for r in 0..p {
                    assert_eq!(
                        thread[r].0, uds[r],
                        "{:?} {wname} {} p={p} r={r}: thread and uds backends disagree",
                        T::DTYPE, sched.name
                    );
                }
            }
        }
    }
}

#[test]
fn thread_and_uds_bit_identical_every_generator_i64() {
    assert_cross_backend_identity::<i64>(17);
}

#[test]
fn thread_and_uds_bit_identical_every_generator_u64() {
    assert_cross_backend_identity::<u64>(23);
}

#[test]
fn uds_matches_the_exact_fold_oracle_i64() {
    // Beyond agreeing with the thread backend, the socket backend must
    // compute the unique wrapping-sum answer on the region each
    // collective's semantics define — allreduce everywhere, the owned
    // block for reduce-scatter — over regular and zipf partitions.
    let _guard = serial();
    for p in [2usize, 5, 8] {
        let m = 7 * p + 3;
        for (wname, part) in partitions(p, m) {
            let inputs = inputs_for::<i64>(p, part.total(), 400 + p as u64);
            let want = fold_oracle::<i64>(&inputs, &SumOp);
            for alg_name in ["rs", "ar"] {
                let sched = Algorithm::parse(alg_name).unwrap().schedule(p);
                let uds = run_uds::<i64>(&sched, &part, &inputs, "oracle");
                for (r, buf) in uds.iter().enumerate() {
                    let range =
                        if alg_name == "ar" { 0..part.total() } else { part.range(r) };
                    assert_eq!(
                        &buf[range.clone()],
                        &want[range],
                        "{wname} {alg_name} p={p} r={r}: uds result is wrong"
                    );
                }
            }
        }
    }
}

#[test]
fn uds_engine_mini_soak_spawns_once_per_process() {
    let _guard = serial();
    let p = 4usize;
    let n_ops = 120usize; // ≥ 100, windowed so several stay in flight
    let window = 8usize;
    let before = rank_threads_spawned();
    let dir = scratch_dir("soak");
    let transports = uds_network_typed::<i64>(p, &dir).expect("uds bootstrap");
    let mut engine = CollectiveEngine::with_transports(EngineConfig::new(p), transports);

    let mut rng = SplitMix64::new(0x50AC);
    let sizes = [8usize, 17, 33, 64];
    let mut pending: std::collections::VecDeque<(Vec<i64>, _)> =
        std::collections::VecDeque::with_capacity(window);
    let mut drain = |pending: &mut std::collections::VecDeque<(Vec<i64>, _)>| {
        let (want, handle): (Vec<i64>, circulant_collectives::engine::OpHandle<i64, _>) =
            pending.pop_front().expect("nonempty window");
        let out = handle.wait().expect("soak op");
        for (r, buf) in out.iter().enumerate() {
            assert_eq!(buf, &want, "soak rank {r}");
        }
    };
    for i in 0..n_ops {
        let m = sizes[i % sizes.len()];
        let inputs: Vec<Vec<i64>> =
            (0..p).map(|_| int_vec(&mut rng, m, -8, 9)).collect();
        let want = fold_oracle::<i64>(&inputs, &SumOp);
        let handle = engine.submit(OpRequest::allreduce(inputs, "sum")).expect("submit");
        pending.push_back((want, handle));
        if pending.len() >= window {
            drain(&mut pending);
        }
    }
    while !pending.is_empty() {
        drain(&mut pending);
    }
    let plan_stats = engine.plan_stats();
    engine.shutdown();
    let _ = std::fs::remove_dir_all(&dir);

    // Spawn-once: the soak's only rank threads are the engine's p
    // workers — socket reader threads are transport plumbing, counted
    // nowhere, and nothing may spawn per operation.
    assert_eq!(
        rank_threads_spawned() - before,
        p as u64,
        "uds engine must spawn exactly p rank workers for the whole soak"
    );
    // Repeated shapes must amortize through the plan cache, same as the
    // thread-backend engine.
    assert!(
        plan_stats.hits > plan_stats.misses,
        "soak replayed {} shapes but plan cache saw hits={} misses={}",
        sizes.len(),
        plan_stats.hits,
        plan_stats.misses
    );
}
