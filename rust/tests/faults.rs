//! Failure-path integration tests: seeded fault plans (drop / delay /
//! kill) over the thread and UDS backends, the engine's RankDown
//! fast-fail taxonomy, survivor bit-identity, leak-freedom across long
//! runs of consecutive failures, the backpressure diagnostic, and a
//! real 4-process kill-one-rank run of the `ccoll` binary.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use circulant_collectives::collectives::CollectiveError;
use circulant_collectives::datatypes::{elem, Elem};
use circulant_collectives::engine::{CollectiveEngine, EngineConfig, EngineError, OpRequest};
use circulant_collectives::ops::SumOp;
use circulant_collectives::transport::fault::{
    FaultAction, FaultPlan, FaultRule, FaultTransport,
};
use circulant_collectives::transport::uds::uds_network_typed;
use circulant_collectives::transport::{network_typed, Endpoint, TransportError};
use circulant_collectives::util::rng::SplitMix64;

type FaultNet = FaultTransport<i64, Endpoint<i64>>;

/// Integer-valued inputs + exact scalar sum oracle.
fn sum_case(p: usize, m: usize, seed: u64) -> (Vec<Vec<i64>>, Vec<i64>) {
    let (lo, hi) = elem::test_value_bounds(<i64 as Elem>::DTYPE);
    let mut rng = SplitMix64::new(seed);
    let inputs: Vec<Vec<i64>> = (0..p).map(|_| elem::int_vec(&mut rng, m, lo, hi)).collect();
    let mut want = vec![0i64; m];
    for v in &inputs {
        SumOp.combine(&mut want, v);
    }
    (inputs, want)
}

fn fault_engine(
    p: usize,
    plan: &FaultPlan,
    cfg: EngineConfig,
) -> CollectiveEngine<i64, FaultNet> {
    let transports: Vec<FaultNet> = network_typed::<i64>(p)
        .into_iter()
        .map(|ep| FaultTransport::new(ep, plan.clone()))
        .collect();
    CollectiveEngine::with_transports(cfg, transports)
}

fn scratch(tag: &str, p: usize) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "ccoll-faults-{tag}-{p}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create scratch dir");
    dir
}

fn assert_rank_down(err: &EngineError, want_peer: usize, ctx: &str) {
    match err {
        EngineError::Collective {
            source: CollectiveError::RankDown { peer, .. },
            ..
        } => assert_eq!(
            *peer, want_peer,
            "{ctx}: RankDown names peer {peer}, want the killed rank {want_peer}"
        ),
        other => panic!("{ctx}: want CollectiveError::RankDown, got: {other}"),
    }
}

/// A fault-injected kill fails subsequent ops with the `RankDown`
/// taxonomy (positive death detection), never a bare liveness timeout —
/// and everything that completed before the kill is bit-exact.
#[test]
fn kill_fails_ops_with_rank_down_not_timeout_thread() {
    for p in [2usize, 5, 8] {
        let killed = p - 1;
        let plan = FaultPlan::new(0xBAD5_EED0).kill_rank(killed, 3);
        let mut engine = fault_engine(
            p,
            &plan,
            EngineConfig::new(p).op_timeout(Duration::from_millis(400)),
        );
        // Ops 1 and 2 predate the kill epoch: they complete bit-exact.
        for i in 0..2u64 {
            let (inputs, want) = sum_case(p, 64, 100 + i);
            let out = engine
                .submit(OpRequest::allreduce(inputs, "sum"))
                .unwrap()
                .wait()
                .unwrap_or_else(|e| panic!("p={p}: pre-kill op {} must survive: {e}", i + 1));
            for (r, buf) in out.iter().enumerate() {
                assert_eq!(buf[..], want[..], "p={p} rank {r}: pre-kill result diverges");
            }
        }
        // From op 3 on, rank p-1 is dead: RankDown, bounded by 2× the
        // op timeout per wait (the hang bound).
        for i in 0..3u64 {
            let (inputs, _) = sum_case(p, 64, 200 + i);
            let handle = engine.submit(OpRequest::allreduce(inputs, "sum")).unwrap();
            let t0 = Instant::now();
            let err = handle.wait().expect_err("op past the kill epoch must fail");
            let waited = t0.elapsed();
            assert!(
                waited < Duration::from_millis(800),
                "p={p}: failed wait took {waited:?}, over the 2×op-timeout hang bound"
            );
            assert_rank_down(&err, killed, &format!("p={p} post-kill op {}", i + 3));
        }
        engine.shutdown();
    }
}

/// Seeded sub-timeout delays are survivable chaos: every op completes
/// and stays bit-exact (the schedule tolerates slow links, only dead
/// ones fail it).
#[test]
fn seeded_delays_preserve_results_thread() {
    let p = 5;
    let plan = FaultPlan::new(0xDE1A_4)
        .rule(FaultRule::new(FaultAction::Delay(Duration::from_millis(2))).with_probability(0.4));
    let mut engine = fault_engine(
        p,
        &plan,
        EngineConfig::new(p).op_timeout(Duration::from_secs(5)),
    );
    for i in 0..30u64 {
        let (inputs, want) = sum_case(p, 48, 300 + i);
        let out = engine
            .submit(OpRequest::allreduce(inputs, "sum"))
            .unwrap()
            .wait()
            .unwrap_or_else(|e| panic!("delayed op {i} must still complete: {e}"));
        for (r, buf) in out.iter().enumerate() {
            assert_eq!(buf[..], want[..], "op {i} rank {r}: delay changed the result");
        }
    }
    engine.shutdown();
}

/// A dropped message (sender alive, frame black-holed) is a *silent*
/// stall: the taxonomy is the liveness `Timeout`, NOT `RankDown` — and
/// the engine recovers for the next op.
#[test]
fn dropped_message_times_out_and_engine_recovers() {
    let p = 2;
    // Drop every frame rank 1 sends for op epoch 1.
    let plan =
        FaultPlan::new(0xD0_D0).rule(FaultRule::new(FaultAction::Drop).on_rank(1).at_op(1));
    let mut engine = fault_engine(
        p,
        &plan,
        EngineConfig::new(p).op_timeout(Duration::from_millis(300)),
    );
    let (inputs, _) = sum_case(p, 32, 400);
    let err = engine
        .submit(OpRequest::allreduce(inputs, "sum"))
        .unwrap()
        .wait()
        .expect_err("op 1 is wedged by the drop rule");
    match &err {
        EngineError::Collective {
            source:
                CollectiveError::Transport(
                    TransportError::Timeout { .. } | TransportError::AckTimeout { .. },
                ),
            ..
        } => {}
        other => panic!("a drop must surface as a liveness Timeout, got: {other}"),
    }
    // Op 2 is untouched by the rule: the engine cleaned up and recovered.
    let (inputs, want) = sum_case(p, 32, 401);
    let out = engine
        .submit(OpRequest::allreduce(inputs, "sum"))
        .unwrap()
        .wait()
        .expect("op 2 must complete after the wedged op was failed + cleaned");
    for (r, buf) in out.iter().enumerate() {
        assert_eq!(buf[..], want[..], "rank {r}: post-recovery result diverges");
    }
    engine.shutdown();
}

/// ≥ 50 consecutive failed ops leak nothing: every failure releases its
/// queue slot (a leak would wedge submission into BackpressureTimeout
/// with queue_depth 2 long before 60 failures) and in-flight accounting
/// drains to zero.
#[test]
fn sixty_consecutive_failed_ops_leak_no_slots() {
    let p = 2;
    let killed = 1;
    let plan = FaultPlan::new(0x1EAC).kill_rank(killed, 1); // dead from the first op
    let mut engine = fault_engine(
        p,
        &plan,
        EngineConfig::new(p)
            .queue_depth(2)
            .op_timeout(Duration::from_millis(400))
            .backpressure_timeout(Duration::from_secs(5)),
    );
    for i in 0..60u64 {
        let (inputs, _) = sum_case(p, 16, 500 + i);
        let err = engine
            .submit(OpRequest::allreduce(inputs, "sum"))
            .unwrap_or_else(|e| panic!("submit {i} wedged — a failed op leaked its slot: {e}"))
            .wait()
            .expect_err("every op needs the dead rank");
        assert_rank_down(&err, killed, &format!("failure #{i}"));
    }
    let deadline = Instant::now() + Duration::from_secs(2);
    while engine.in_flight() > 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_micros(200));
    }
    assert_eq!(
        engine.in_flight(),
        0,
        "in-flight slots never drained after 60 consecutive failures"
    );
    engine.shutdown();
}

/// The same kill taxonomy holds over the UDS backend: a fault-wrapped
/// socket mesh in one process, p ∈ {2, 5, 8}.
#[test]
fn uds_fault_kill_rank_down_taxonomy() {
    for p in [2usize, 5, 8] {
        let killed = p - 1;
        let dir = scratch("kill", p);
        let nets = uds_network_typed::<i64>(p, &dir).expect("uds bootstrap");
        let plan = FaultPlan::new(0x0D5).kill_rank(killed, 2);
        let transports: Vec<_> =
            nets.into_iter().map(|t| FaultTransport::new(t, plan.clone())).collect();
        let mut engine = CollectiveEngine::<i64, _>::with_transports(
            EngineConfig::new(p).op_timeout(Duration::from_millis(500)),
            transports,
        );
        // Op 1 predates the kill: bit-exact over the wire.
        let (inputs, want) = sum_case(p, 32, 600);
        let out = engine
            .submit(OpRequest::allreduce(inputs, "sum"))
            .unwrap()
            .wait()
            .unwrap_or_else(|e| panic!("uds p={p}: pre-kill op must survive: {e}"));
        for (r, buf) in out.iter().enumerate() {
            assert_eq!(buf[..], want[..], "uds p={p} rank {r}: pre-kill result diverges");
        }
        // Ops 2 and 3: RankDown naming the killed rank.
        for i in 0..2u64 {
            let (inputs, _) = sum_case(p, 32, 601 + i);
            let err = engine
                .submit(OpRequest::allreduce(inputs, "sum"))
                .unwrap()
                .wait()
                .expect_err("op past the kill epoch must fail");
            assert_rank_down(&err, killed, &format!("uds p={p} post-kill op {}", i + 2));
        }
        engine.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// A drop over UDS frames surfaces as the liveness Timeout taxonomy
/// (sender alive, wire silent) — the backend distinction the RankDown
/// error exists to draw.
#[test]
fn uds_dropped_frame_times_out() {
    let p = 2;
    let dir = scratch("drop", p);
    let nets = uds_network_typed::<i64>(p, &dir).expect("uds bootstrap");
    let plan =
        FaultPlan::new(0xD2_0F).rule(FaultRule::new(FaultAction::Drop).on_rank(0).at_op(1));
    let transports: Vec<_> =
        nets.into_iter().map(|t| FaultTransport::new(t, plan.clone())).collect();
    let mut engine = CollectiveEngine::<i64, _>::with_transports(
        EngineConfig::new(p).op_timeout(Duration::from_millis(300)),
        transports,
    );
    let (inputs, _) = sum_case(p, 24, 700);
    let err = engine
        .submit(OpRequest::allreduce(inputs, "sum"))
        .unwrap()
        .wait()
        .expect_err("op 1 is wedged by the drop rule");
    match &err {
        EngineError::Collective {
            source:
                CollectiveError::Transport(
                    TransportError::Timeout { .. } | TransportError::AckTimeout { .. },
                ),
            ..
        } => {}
        other => panic!("uds drop must surface as a liveness Timeout, got: {other}"),
    }
    engine.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// The backpressure diagnostic names the wedged op: queue_depth 1, op 1
/// stalled by a drop rule, op 2's submit must fail with
/// `BackpressureTimeout` carrying `stuck_tags == [1]`.
#[test]
fn backpressure_timeout_names_stuck_tags() {
    let p = 2;
    let plan =
        FaultPlan::new(0xB4_C4).rule(FaultRule::new(FaultAction::Drop).on_rank(1).at_op(1));
    let mut engine = fault_engine(
        p,
        &plan,
        EngineConfig::new(p)
            .queue_depth(1)
            .op_timeout(Duration::from_secs(3))
            .backpressure_timeout(Duration::from_secs(1)),
    );
    let (inputs, _) = sum_case(p, 16, 800);
    let wedged = engine.submit(OpRequest::allreduce(inputs, "sum")).unwrap();
    let (inputs, _) = sum_case(p, 16, 801);
    match engine.submit(OpRequest::allreduce(inputs, "sum")) {
        Err(EngineError::BackpressureTimeout { stuck_tags, in_flight, .. }) => {
            assert_eq!(stuck_tags, vec![1], "the diagnostic must name the wedged op tag");
            assert_eq!(in_flight, 1);
        }
        Ok(_) => panic!("submit must park then fail: queue_depth 1 and op 1 is wedged"),
        Err(other) => panic!("want BackpressureTimeout, got: {other}"),
    }
    // The wedged op eventually fails on its liveness watchdog and the
    // engine tears down cleanly.
    let err = wedged.wait().expect_err("the wedged op can never complete");
    assert!(
        matches!(
            err,
            EngineError::Collective {
                source: CollectiveError::Transport(
                    TransportError::Timeout { .. } | TransportError::AckTimeout { .. }
                ),
                ..
            }
        ),
        "want a liveness timeout for the wedged op, got: {err}"
    );
    engine.shutdown();
}

/// Fused-batch members get failed too: with fusion on and a rank killed
/// from the first epoch, every submitted member op must settle with an
/// error (RankDown directly, or the FusedBatch wrapper naming the
/// batch) — none may hang.
#[test]
fn fused_members_fail_under_kill() {
    let p = 2;
    let plan = FaultPlan::new(0xF0_5E).kill_rank(1, 1);
    let mut engine = fault_engine(
        p,
        &plan,
        EngineConfig::new(p)
            .fusion(true)
            .fusion_window(4)
            .op_timeout(Duration::from_millis(400)),
    );
    let mut handles = Vec::new();
    for i in 0..6u64 {
        let (inputs, _) = sum_case(p, 8, 900 + i);
        handles.push(engine.submit(OpRequest::allreduce(inputs, "sum")).unwrap());
    }
    for (i, h) in handles.into_iter().enumerate() {
        let t0 = Instant::now();
        let err = h.wait().expect_err("every member needs the dead rank");
        assert!(
            t0.elapsed() < Duration::from_millis(800),
            "member {i}: wait exceeded the 2×op-timeout hang bound"
        );
        let ok = matches!(
            &err,
            EngineError::Collective {
                source: CollectiveError::RankDown { .. } | CollectiveError::FusedBatch { .. },
                ..
            }
        );
        assert!(ok, "member {i}: want RankDown or FusedBatch taxonomy, got: {err}");
    }
    engine.shutdown();
}

/// Distinct seeds produce distinct drop patterns, same seed reproduces
/// exactly — the chaos soak is replayable from its seed alone.
#[test]
fn fault_plan_soak_is_reproducible_from_seed() {
    let run = |seed: u64| -> Vec<bool> {
        let p = 3;
        let plan = FaultPlan::new(seed)
            .rule(FaultRule::new(FaultAction::Drop).with_probability(0.05));
        let mut engine = fault_engine(
            p,
            &plan,
            EngineConfig::new(p).op_timeout(Duration::from_millis(200)),
        );
        let mut outcomes = Vec::new();
        for i in 0..12u64 {
            let (inputs, want) = sum_case(p, 16, 1000 + i);
            let done = match engine.submit(OpRequest::allreduce(inputs, "sum")).unwrap().wait() {
                Ok(out) => {
                    for buf in &out {
                        assert_eq!(buf[..], want[..], "survivor must stay bit-exact");
                    }
                    true
                }
                Err(_) => false,
            };
            outcomes.push(done);
        }
        engine.shutdown();
        outcomes
    };
    let a = run(21);
    assert_eq!(a, run(21), "same seed must reproduce the exact outcome vector");
    assert!(a.iter().any(|&ok| ok), "p=0.15 drops should leave some survivors");
}

/// THE acceptance test: 4 real `ccoll launch` processes over UDS,
/// SIGKILL one mid-soak — every survivor must detect the death (reader
/// EOF → PeerDown → nonzero exit) within a tight budget. No hang, no
/// zero exit.
#[test]
fn four_process_kill_one_rank_survivors_exit_nonzero() {
    use std::process::{Command, Stdio};
    let bin = env!("CARGO_BIN_EXE_ccoll");
    let dir = scratch("proc", 4);
    let dir_s = dir.to_str().unwrap().to_string();
    let mut children: Vec<_> = (0..4)
        .map(|r| {
            Command::new(bin)
                .args([
                    "launch",
                    "--backend",
                    "uds",
                    "--rank",
                    &r.to_string(),
                    "--world",
                    "4",
                    "--dir",
                    &dir_s,
                    "--launch.m",
                    "4096",
                    "--launch.iters",
                    "1000000",
                    "--launch.verify",
                    "0",
                ])
                .stdout(Stdio::null())
                .stderr(Stdio::null())
                .spawn()
                .expect("spawn ccoll launch")
        })
        .collect();
    // Let the mesh bootstrap and the iteration soak begin, then kill
    // rank 3 outright (SIGKILL — no graceful shutdown path runs).
    std::thread::sleep(Duration::from_millis(1500));
    children[3].kill().expect("kill rank 3");
    let _ = children[3].wait();

    let deadline = Instant::now() + Duration::from_secs(30);
    let mut statuses: Vec<Option<std::process::ExitStatus>> = vec![None; 3];
    while Instant::now() < deadline && statuses.iter().any(Option::is_none) {
        for (r, slot) in statuses.iter_mut().enumerate() {
            if slot.is_none() {
                *slot = children[r].try_wait().expect("try_wait");
            }
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    // Reap anything still running before asserting, so a failure can't
    // strand processes.
    for c in children.iter_mut() {
        let _ = c.kill();
        let _ = c.wait();
    }
    let _ = std::fs::remove_dir_all(&dir);
    for (r, slot) in statuses.iter().enumerate() {
        let Some(status) = slot else {
            panic!("rank {r} did not exit within 30s of rank 3's kill — death undetected (hang)")
        };
        assert!(
            !status.success(),
            "rank {r} exited 0 after its peer was killed — the failure went undetected"
        );
    }
}

/// Drain-mode shutdown under chaos: the in-flight failure settles (it
/// does not hang the drain), new submissions are refused, and no slot
/// is left in flight.
#[test]
fn drain_shutdown_after_kill_refuses_new_work() {
    let p = 2;
    let plan = FaultPlan::new(0xD4_A1).kill_rank(1, 2);
    let mut engine = fault_engine(
        p,
        &plan,
        EngineConfig::new(p).op_timeout(Duration::from_millis(300)),
    );
    // Op 1 completes before the kill epoch is ever observed.
    let (inputs, want) = sum_case(p, 16, 1100);
    let out = engine
        .submit(OpRequest::allreduce(inputs, "sum"))
        .unwrap()
        .wait()
        .expect("op 1 predates the kill epoch");
    for buf in &out {
        assert_eq!(buf[..], want[..], "pre-kill op must stay bit-exact");
    }
    // Op 2 trips the kill and is in flight when the drain starts.
    let (inputs, _) = sum_case(p, 16, 1101);
    let doomed = engine.submit(OpRequest::allreduce(inputs, "sum")).unwrap();
    engine.drain_shutdown();
    let (inputs, _) = sum_case(p, 16, 1102);
    match engine.submit(OpRequest::allreduce(inputs, "sum")) {
        Err(EngineError::ShutDown) => {}
        Ok(_) => panic!("submit after drain_shutdown must be refused"),
        Err(other) => panic!("want ShutDown after drain, got: {other}"),
    }
    assert_rank_down(&doomed.wait().expect_err("op 2 hits the kill"), 1, "drained kill victim");
    // Every op settled ⇒ nothing left in flight.
    assert_eq!(engine.in_flight(), 0);
}
