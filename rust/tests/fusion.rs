//! Fusion-tier correctness suite (ISSUE-5): a fused batch of mixed-length
//! allreduces / reduce-scatters must be bit-identical to sequential
//! unfused execution in the exact integer dtypes, across both copy tiers
//! — including zero-length member ops (PR-3's empty-payload audit must
//! hold through pack/scatter).
//!
//! CI runs this suite twice: as-is (rendezvous tier active where
//! schedules allow) and under `CCOLL_NO_RENDEZVOUS=1` (pooled tier only).

use std::sync::{Mutex, MutexGuard};

use circulant_collectives::cli::main_with_args;
use circulant_collectives::datatypes::{elem, BlockPartition, Elem};
use circulant_collectives::engine::{CollectiveEngine, EngineConfig, OpRequest};
use circulant_collectives::ops::SumOp;
use circulant_collectives::util::json::Json;
use circulant_collectives::util::rng::SplitMix64;

/// Serialize tests that assert on the process-global rank-thread-spawn
/// counter (`ccoll serve` does so internally).
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

fn int_inputs<T: Elem>(p: usize, m: usize, seed: u64) -> Vec<Vec<T>> {
    let (lo, hi) = elem::test_value_bounds(T::DTYPE);
    let mut rng = SplitMix64::new(seed);
    (0..p).map(|_| elem::int_vec(&mut rng, m, lo, hi)).collect()
}

/// An engine whose pending batch only ever flushes when forced by a
/// handle wait — deterministic batch composition for the tests.
fn engine_with<T: Elem>(p: usize, rendezvous: bool, fusion: bool) -> CollectiveEngine<T> {
    CollectiveEngine::new(
        EngineConfig::new(p)
            .rendezvous(rendezvous)
            .rendezvous_min_elems(0)
            .fusion(fusion)
            .fusion_window(1_000_000)
            .fusion_max_bytes(1 << 24),
    )
}

/// Mixed-length member ops, including a zero-length one in the middle.
fn member_lens(p: usize) -> Vec<usize> {
    vec![4 * p + 3, 16, 0, 2 * p, 64, 1]
}

/// Run the given (kind, lens) workload: submit all, then wait in reverse
/// submission order. With `fusion` on, the whole set rides one fused run
/// (same kind + op, unbounded window); off, each op runs alone.
fn run_batch<T: Elem>(
    p: usize,
    lens: &[usize],
    allreduce: bool,
    rendezvous: bool,
    fusion: bool,
    seed: u64,
) -> Vec<Vec<Vec<T>>> {
    let mut engine = engine_with::<T>(p, rendezvous, fusion);
    let handles: Vec<_> = lens
        .iter()
        .enumerate()
        .map(|(i, &m)| {
            let inputs = int_inputs::<T>(p, m, seed.wrapping_mul(131).wrapping_add(i as u64));
            let req = if allreduce {
                OpRequest::allreduce(inputs, "sum")
            } else {
                OpRequest::reduce_scatter(inputs, "sum")
            };
            engine.submit(req).unwrap()
        })
        .collect();
    let n = handles.len();
    let mut out: Vec<Option<Vec<Vec<T>>>> = (0..n).map(|_| None).collect();
    for (i, handle) in handles.into_iter().enumerate().rev() {
        out[i] = Some(handle.wait().unwrap());
    }
    if fusion {
        let s = engine.fusion_stats();
        assert_eq!(s.batches, 1, "the whole set must ride one fused run: {s:?}");
        assert_eq!(s.fused_ops as usize, lens.len(), "{s:?}");
    }
    engine.shutdown();
    out.into_iter().map(|r| r.unwrap()).collect()
}

#[test]
fn fused_allreduce_bit_identical_to_unfused_i64_both_tiers() {
    let _serial = serial();
    for p in [2usize, 5, 8] {
        for rendezvous in [true, false] {
            let lens = member_lens(p);
            let fused = run_batch::<i64>(p, &lens, true, rendezvous, true, 9 + p as u64);
            let unfused = run_batch::<i64>(p, &lens, true, rendezvous, false, 9 + p as u64);
            assert_eq!(
                fused, unfused,
                "p={p} rendezvous={rendezvous}: fused allreduce ≠ unfused (bit-exact i64)"
            );
        }
    }
}

#[test]
fn fused_allreduce_bit_identical_to_unfused_u64() {
    let _serial = serial();
    let p = 5;
    let lens = member_lens(p);
    for rendezvous in [true, false] {
        let fused = run_batch::<u64>(p, &lens, true, rendezvous, true, 77);
        let unfused = run_batch::<u64>(p, &lens, true, rendezvous, false, 77);
        assert_eq!(fused, unfused, "rendezvous={rendezvous}: u64 fused batch diverged");
    }
}

#[test]
fn fused_reduce_scatter_owned_blocks_bit_identical_and_oracle_exact() {
    let _serial = serial();
    // Reduce-scatter semantics: block r is finished at rank r. The fused
    // run must deliver each member's owned block bit-identical to the
    // unfused run AND to the wrapping scalar fold of its own inputs.
    for p in [2usize, 5, 8] {
        for rendezvous in [true, false] {
            let lens = member_lens(p);
            let fused = run_batch::<i64>(p, &lens, false, rendezvous, true, 40 + p as u64);
            let unfused = run_batch::<i64>(p, &lens, false, rendezvous, false, 40 + p as u64);
            for (i, &m) in lens.iter().enumerate() {
                let seed = (40 + p as u64).wrapping_mul(131).wrapping_add(i as u64);
                let inputs = int_inputs::<i64>(p, m, seed);
                let mut want = vec![0i64; m];
                for v in &inputs {
                    SumOp.combine(&mut want, v);
                }
                let part = BlockPartition::regular(p, m);
                for r in 0..p {
                    let range = part.range(r);
                    assert_eq!(
                        &fused[i][r][range.clone()],
                        &unfused[i][r][range.clone()],
                        "p={p} rendezvous={rendezvous} op {i} rank {r}: fused ≠ unfused"
                    );
                    assert_eq!(
                        &fused[i][r][range.clone()],
                        &want[range],
                        "p={p} rendezvous={rendezvous} op {i} rank {r}: fused ≠ oracle"
                    );
                }
            }
        }
    }
}

#[test]
fn zero_length_member_survives_pack_scatter() {
    let _serial = serial();
    // Explicit regression for the empty-payload audit through the fusion
    // tier: an m=0 member inside a real batch resolves to an empty result
    // on every rank, and its neighbors are unaffected.
    let p = 4;
    let mut engine = engine_with::<i64>(p, true, true);
    let a = int_inputs::<i64>(p, 24, 1);
    let mut want_a = vec![0i64; 24];
    for v in &a {
        SumOp.combine(&mut want_a, v);
    }
    let empty: Vec<Vec<i64>> = vec![Vec::new(); p];
    let b = int_inputs::<i64>(p, 7, 2);
    let mut want_b = vec![0i64; 7];
    for v in &b {
        SumOp.combine(&mut want_b, v);
    }
    let ha = engine.submit(OpRequest::allreduce(a, "sum")).unwrap();
    let he = engine.submit(OpRequest::allreduce(empty, "sum")).unwrap();
    let hb = engine.submit(OpRequest::allreduce(b, "sum")).unwrap();
    let out_e = he.wait().unwrap();
    for (r, buf) in out_e.iter().enumerate() {
        assert!(buf.is_empty(), "rank {r}: zero-length member must stay empty");
    }
    for buf in ha.wait().unwrap() {
        assert_eq!(buf, want_a);
    }
    for buf in hb.wait().unwrap() {
        assert_eq!(buf, want_b);
    }
    assert_eq!(engine.fusion_stats().batches, 1);
    engine.shutdown();
}

#[test]
fn mixed_kind_traffic_fuses_per_kind_and_stays_exact() {
    let _serial = serial();
    // Alternating allreduce / reduce-scatter: each kind switch flushes the
    // pending batch, results stay oracle-exact throughout.
    let p = 4;
    let mut engine = engine_with::<i64>(p, true, true);
    let mut handles = Vec::new();
    let mut oracles = Vec::new();
    let mut kinds = Vec::new();
    let mut sizes = Vec::new();
    for i in 0..12u64 {
        let m = [16usize, 33, 8][i as usize % 3];
        let inputs = int_inputs::<i64>(p, m, 600 + i);
        let mut want = vec![0i64; m];
        for v in &inputs {
            SumOp.combine(&mut want, v);
        }
        let allreduce = (i / 2) % 2 == 0; // pairs: ar, ar, rs, rs, …
        let req = if allreduce {
            OpRequest::allreduce(inputs, "sum")
        } else {
            OpRequest::reduce_scatter(inputs, "sum")
        };
        handles.push(engine.submit(req).unwrap());
        oracles.push(want);
        kinds.push(allreduce);
        sizes.push(m);
    }
    for (i, handle) in handles.into_iter().enumerate() {
        let out = handle.wait().unwrap();
        let part = BlockPartition::regular(p, sizes[i]);
        for (r, buf) in out.iter().enumerate() {
            if kinds[i] {
                assert_eq!(buf, &oracles[i], "op {i} rank {r}");
            } else {
                let range = part.range(r);
                assert_eq!(&buf[range.clone()], &oracles[i][range], "op {i} rank {r}");
            }
        }
    }
    let s = engine.fusion_stats();
    assert!(s.batches >= 2, "kind alternation must still form batches: {s:?}");
    assert!(s.flush_incompatible >= 1, "kind switches must flush: {s:?}");
    engine.shutdown();
}

#[test]
fn fused_plan_cache_hits_on_repeated_batch_shapes() {
    let _serial = serial();
    let p = 4;
    let mut engine = engine_with::<i64>(p, true, true);
    for round in 0..3u64 {
        let handles: Vec<_> = [8usize, 24, 8]
            .iter()
            .enumerate()
            .map(|(i, &m)| {
                let inputs = int_inputs::<i64>(p, m, 900 + round * 10 + i as u64);
                engine.submit(OpRequest::allreduce(inputs, "sum")).unwrap()
            })
            .collect();
        for h in handles {
            h.wait().unwrap();
        }
    }
    let s = engine.fusion_stats();
    assert_eq!(s.batches, 3, "{s:?}");
    assert_eq!(s.plan_misses, 1, "one build for the repeated batch shape: {s:?}");
    assert_eq!(s.plan_hits, 2, "rounds 2 and 3 must hit the fused plan: {s:?}");
    engine.shutdown();
}

#[test]
fn fusion_soak_spawns_once_and_reuses_plans() {
    let _serial = serial();
    // 400 mixed small ops through one fused engine: spawn-once plus a
    // bounded plan set (few distinct batch shapes are NOT guaranteed —
    // batch composition varies — but fused plans must hit eventually).
    let p = 4;
    let before = circulant_collectives::transport::rank_threads_spawned();
    let mut engine = CollectiveEngine::<i64>::new(
        EngineConfig::new(p).fusion(true).fusion_window(8).fusion_max_bytes(1 << 16),
    );
    let mut window = std::collections::VecDeque::new();
    let mut rng = SplitMix64::new(321);
    for i in 0..400u64 {
        let m = [8usize, 16, 32][rng.next_below(3)];
        let inputs = int_inputs::<i64>(p, m, 5000 + i);
        let req = if rng.next_below(2) == 0 {
            OpRequest::allreduce(inputs, "sum")
        } else {
            OpRequest::reduce_scatter(inputs, "sum")
        };
        window.push_back(engine.submit(req).unwrap());
        if window.len() >= 16 {
            window.pop_front().unwrap().wait().unwrap();
        }
    }
    while let Some(h) = window.pop_front() {
        h.wait().unwrap();
    }
    let s = engine.fusion_stats();
    engine.shutdown();
    let spawned = circulant_collectives::transport::rank_threads_spawned() - before;
    assert_eq!(spawned, p as u64, "fusion must not add any thread spawns");
    assert!(s.batches > 0, "400 compatible-rich ops must form batches: {s:?}");
    assert!(s.plan_hits > 0, "repeated shapes must hit the fused plan cache: {s:?}");
}

// ---------------------------------------------------------------------
// `ccoll serve --fuse` — the replay driver end-to-end.
// ---------------------------------------------------------------------

fn args(s: &[&str]) -> Vec<String> {
    s.iter().map(|x| x.to_string()).collect()
}

#[test]
fn serve_fuse_soaks_and_reports_percentiles_and_fusion_stats() {
    let _serial = serial();
    let dir = std::env::temp_dir();
    let json_path = dir.join(format!("ccoll_serve_fuse_{}.json", std::process::id()));
    main_with_args(args(&[
        "serve",
        "--fuse",
        "--serve.p",
        "4",
        "--serve.ops",
        "300",
        "--serve.m",
        "128",
        "--serve.inflight",
        "16",
        "--serve.json",
        json_path.to_str().unwrap(),
    ]))
    .unwrap();
    let doc = Json::parse(&std::fs::read_to_string(&json_path).unwrap()).unwrap();
    std::fs::remove_file(&json_path).ok();
    // Latency percentiles recorded in the serve JSON output.
    for key in ["lat_mean_s", "lat_p50_s", "lat_p95_s", "lat_p99_s", "ops_per_sec"] {
        let v = doc.req(key).as_f64().unwrap_or_else(|| panic!("{key} must be numeric"));
        assert!(v.is_finite() && v >= 0.0, "{key} = {v}");
    }
    assert_eq!(doc.req("dtype").as_str(), Some("f32"));
    assert_eq!(doc.req("ops").as_usize(), Some(300));
    let fusion = doc.req("fusion");
    assert!(fusion.req("batches").as_usize().unwrap() > 0, "soak must fuse");
    assert!(fusion.req("plan_hits").as_usize().unwrap() > 0, "fused plans must hit");
    assert_eq!(doc.req("rank_threads_spawned").as_usize(), Some(4), "spawn-once through --fuse");
}

#[test]
fn serve_fuse_rejects_zero_window() {
    let _serial = serial();
    let err = main_with_args(args(&[
        "serve",
        "--fuse",
        "--serve.p",
        "2",
        "--serve.ops",
        "4",
        "--engine.fusion.window",
        "0",
    ]))
    .unwrap_err();
    assert!(err.to_string().contains("window 0"), "{err}");
}
