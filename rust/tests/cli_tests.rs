//! CLI integration tests: every subcommand through `cli::main_with_args`
//! (in-process; no external process spawning needed).

use circulant_collectives::cli::main_with_args;

fn args(s: &[&str]) -> Vec<String> {
    s.iter().map(|x| x.to_string()).collect()
}

#[test]
fn help_and_info_run() {
    main_with_args(args(&["help"])).unwrap();
    main_with_args(args(&["info"])).unwrap();
}

#[test]
fn unknown_command_errors() {
    assert!(main_with_args(args(&["frobnicate"])).is_err());
}

#[test]
fn run_verifies_small_collective() {
    main_with_args(args(&[
        "run",
        "--run.p",
        "5",
        "--run.m",
        "64",
        "--run.algorithm",
        "allreduce",
    ]))
    .unwrap();
}

#[test]
fn run_supports_baselines_and_schemes() {
    for alg in ["ring-allreduce", "rec-doubling-allreduce", "rabenseifner", "ar:sqrt", "rs:full"] {
        main_with_args(args(&["run", "--run.p", "6", "--run.m", "30", "--run.algorithm", alg]))
            .unwrap_or_else(|e| panic!("{alg}: {e}"));
    }
}

#[test]
fn run_rejects_bad_algorithm() {
    assert!(main_with_args(args(&["run", "--run.algorithm", "bogus"])).is_err());
}

#[test]
fn run_executes_every_dtype_allreduce_and_reduce_scatter() {
    // ISSUE-3 acceptance: `ccoll run` executes (and exactly verifies)
    // allreduce and reduce_scatter in every supported dtype.
    for dtype in ["f32", "f64", "i32", "i64", "u64"] {
        for alg in ["allreduce", "reduce-scatter"] {
            main_with_args(args(&[
                "run",
                "--run.p",
                "5",
                "--run.m",
                "37",
                "--run.algorithm",
                alg,
                "--run.dtype",
                dtype,
            ]))
            .unwrap_or_else(|e| panic!("{alg} dtype={dtype}: {e}"));
        }
    }
}

#[test]
fn run_and_validate_reject_bad_dtype_listing_valid_values() {
    let err = main_with_args(args(&["run", "--run.dtype", "f16"])).unwrap_err();
    assert!(err.to_string().contains("f32|f64|i32|i64|u64"), "{err}");
    let err = main_with_args(args(&["validate", "--run.dtype", "bf16", "--validate.max_p", "3"]))
        .unwrap_err();
    assert!(err.to_string().contains("f32|f64|i32|i64|u64"), "{err}");
}

#[test]
fn bad_algorithm_error_enumerates_alternatives() {
    let err = main_with_args(args(&["run", "--run.algorithm", "bogus"])).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("ring-allreduce") && msg.contains("rabenseifner"), "{msg}");
}

#[test]
fn bad_op_error_enumerates_alternatives() {
    let err = main_with_args(args(&["run", "--run.op", "xor"])).unwrap_err();
    assert!(err.to_string().contains("sum|prod|min|max"), "{err}");
}

#[test]
fn validate_runs_in_an_integer_dtype() {
    main_with_args(args(&["validate", "--validate.max_p", "12", "--run.dtype", "i64"])).unwrap();
}

#[test]
fn simulate_prints_comparison() {
    main_with_args(args(&["simulate", "--sim.p", "100", "--sim.m", "4096"])).unwrap();
}

#[test]
fn trace_reproduces_p22_and_other_p() {
    main_with_args(args(&["trace"])).unwrap(); // the paper's example
    main_with_args(args(&["trace", "--trace.p", "13", "--trace.rank", "0"])).unwrap();
    main_with_args(args(&["trace", "--trace.p", "10", "--trace.scheme", "full"])).unwrap();
}

#[test]
fn validate_sweep() {
    main_with_args(args(&["validate", "--validate.max_p", "40"])).unwrap();
}

#[test]
fn config_file_plus_override() {
    let dir = std::env::temp_dir().join(format!("ccoll-cli-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("run.toml");
    std::fs::write(
        &path,
        "[run]\np = 4\nm = 32\nalgorithm = \"allreduce\"\n[cost]\nalpha = 1e-6\n",
    )
    .unwrap();
    main_with_args(args(&["--config", path.to_str().unwrap(), "run", "--run.p", "3"])).unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn train_smoke_if_artifacts_present() {
    use circulant_collectives::runtime::{default_artifact_dir, Manifest};
    if Manifest::load(default_artifact_dir()).is_err() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    main_with_args(args(&[
        "train",
        "--train.workers",
        "2",
        "--train.steps",
        "5",
        "--train.log_every",
        "0",
    ]))
    .unwrap();
}
