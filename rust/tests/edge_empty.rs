//! Zero-length edge audit (ISSUE 3): `m = 0` collectives, reduce-scatter
//! with empty blocks, and degenerate single-block partitions must neither
//! panic, nor deadlock in rendezvous ack parking, nor corrupt adjacent
//! data — across all three transport tiers and the whole Communicator API.
//!
//! The transport-level invariants these lean on: empty payloads never
//! publish rendezvous descriptors (`SendSlices::is_empty` guard), so no
//! ack is ever awaited for them; `Endpoint::acquire(_, 0)` bypasses the
//! pool (an empty `Vec` allocates nothing); and zero-length circular
//! ranges resolve to empty slices, which every kernel accepts.

use std::sync::Arc;

use circulant_collectives::collectives::{
    run_schedule_threads_tiered, run_schedule_threads_tiered_typed, Algorithm,
};
use circulant_collectives::coordinator::Launcher;
use circulant_collectives::datatypes::BlockPartition;
use circulant_collectives::ops::SumOp;

#[test]
fn zero_length_allreduce_and_reduce_scatter_all_tiers() {
    // m = 0: every block of every rank is empty; both tiers must complete
    // (no send ever publishes, so no rank can park awaiting an ack) and
    // return empty buffers.
    for p in [2usize, 3, 5, 8] {
        let part = BlockPartition::regular(p, 0);
        for alg_name in ["rs", "ar"] {
            let sched = Algorithm::parse(alg_name).unwrap().schedule(p);
            for rendezvous in [true, false] {
                let inputs: Vec<Vec<f32>> = vec![Vec::new(); p];
                let out = run_schedule_threads_tiered(
                    &sched,
                    &part,
                    Arc::new(SumOp),
                    inputs,
                    rendezvous,
                );
                for (r, (buf, c)) in out.iter().enumerate() {
                    assert!(buf.is_empty(), "{alg_name} p={p} r={r}");
                    assert_eq!(
                        c.rendezvous_hits, 0,
                        "{alg_name} p={p} r={r}: empty payloads must never publish"
                    );
                    assert_eq!(c.elems_sent, 0);
                }
            }
        }
    }
}

#[test]
fn tiny_m_mostly_empty_blocks_exact() {
    // 0 < m < p: only the first m blocks are non-empty (one element each);
    // rounds mix empty and 1-element transfers. Exact in i64.
    for p in [3usize, 5, 22] {
        for m in [1usize, 2, p - 1] {
            let part = BlockPartition::regular(p, m);
            let inputs: Vec<Vec<i64>> =
                (0..p).map(|r| (0..m).map(|j| (r * 10 + j) as i64).collect()).collect();
            let mut want = vec![0i64; m];
            for v in &inputs {
                for (a, b) in want.iter_mut().zip(v) {
                    *a += *b; // values tiny; no overflow
                }
            }
            for alg_name in ["rs", "ar"] {
                let sched = Algorithm::parse(alg_name).unwrap().schedule(p);
                for rendezvous in [true, false] {
                    let out = run_schedule_threads_tiered_typed::<i64>(
                        &sched,
                        &part,
                        Arc::new(SumOp),
                        inputs.clone(),
                        rendezvous,
                    );
                    for (r, (buf, _)) in out.iter().enumerate() {
                        let range =
                            if alg_name == "ar" { 0..m } else { part.range(r) };
                        assert_eq!(
                            &buf[range.clone()],
                            &want[range],
                            "{alg_name} p={p} m={m} r={r} rdv={rendezvous}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn single_block_nonroot_ranks_complete_on_both_tiers() {
    // Degenerate Corollary-3 partition: all m elements in block `root`.
    // Non-root ranks own empty blocks — they forward partials but keep
    // nothing; rendezvous rounds whose payloads are empty must fall back
    // silently rather than park for an ack.
    for p in [2usize, 5, 22] {
        let m = 17usize;
        for root in [0, p / 2, p - 1] {
            let part = BlockPartition::single_block(p, m, root);
            let inputs: Vec<Vec<i64>> =
                (0..p).map(|r| vec![r as i64 + 1; m]).collect();
            let want: i64 = (1..=p as i64).sum();
            let sched = Algorithm::parse("rs").unwrap().schedule(p);
            for rendezvous in [true, false] {
                let out = run_schedule_threads_tiered_typed::<i64>(
                    &sched,
                    &part,
                    Arc::new(SumOp),
                    inputs.clone(),
                    rendezvous,
                );
                // root's block carries the full reduction …
                let (root_buf, _) = &out[root];
                assert!(
                    root_buf[part.range(root)].iter().all(|&x| x == want),
                    "p={p} root={root} rdv={rendezvous}"
                );
                // … and every non-root recv range is empty (nothing to
                // fill — their owned block has zero length).
                for (r, (buf, _)) in out.iter().enumerate() {
                    assert_eq!(buf.len(), m, "p={p} r={r}");
                    if r != root {
                        assert_eq!(part.range(r).len(), 0);
                    }
                }
            }
        }
    }
}

#[test]
fn communicator_zero_length_collectives() {
    // The whole user-facing API at m = 0 / b = 0: nothing may panic,
    // deadlock or return the wrong (non-empty) shape.
    let p = 4usize;
    let out = Launcher::new(p).run(move |mut comm| {
        // allreduce of an empty vector (this is also what barrier does)
        let mut empty: Vec<f32> = Vec::new();
        comm.allreduce(&mut empty, "sum").unwrap();
        assert!(empty.is_empty());

        // reduce_scatter where several ranks own empty blocks
        let counts = vec![0usize, 3, 0, 2];
        let total: usize = counts.iter().sum();
        let send: Vec<f32> = (0..total).map(|j| j as f32).collect();
        let mut recv = vec![f32::NAN; counts[comm.rank()]];
        comm.reduce_scatter(&send, &counts, &mut recv, "sum").unwrap();
        let part = BlockPartition::from_counts(&counts);
        for (i, j) in part.range(comm.rank()).enumerate() {
            assert_eq!(recv[i], (p * j) as f32);
        }

        // reduce-to-root and bcast of empty vectors
        let mut nothing: Vec<f32> = Vec::new();
        comm.reduce(&mut nothing, 1, "sum").unwrap();
        comm.bcast(&mut nothing, 1).unwrap();

        // allgather / scatter / gather with zero-sized blocks
        let mut all: Vec<f32> = Vec::new();
        comm.allgather(&[], &mut all).unwrap();
        assert!(all.is_empty());
        let mut mine: Vec<f32> = Vec::new();
        let root_send: Option<Vec<f32>> = (comm.rank() == 0).then(Vec::new);
        comm.scatter(root_send.as_deref(), &mut mine, 0).unwrap();
        let mut gathered = (comm.rank() == 0).then(Vec::new);
        comm.gather(&mine, gathered.as_deref_mut(), 0).unwrap();

        // all-to-all with empty blocks, regular and irregular
        let got = comm.alltoall(&[], 0).unwrap();
        assert!(got.is_empty());
        let zeros = vec![0usize; p];
        let got = comm.alltoallv(&[], &zeros, &zeros).unwrap();
        assert!(got.is_empty());

        // and the network is still healthy afterwards
        let mut live = vec![comm.rank() as f32];
        comm.allreduce(&mut live, "sum").unwrap();
        live[0]
    });
    let want: f32 = (0..p).map(|r| r as f32).sum();
    assert!(out.iter().all(|&x| x == want), "network unhealthy after zero-length collectives");
}

#[test]
fn reduce_scatter_all_counts_zero() {
    // Fully-empty irregular partition: p blocks, every count 0.
    let p = 5usize;
    let out = Launcher::new(p).run(move |mut comm| {
        let counts = vec![0usize; p];
        let mut recv: Vec<f32> = Vec::new();
        comm.reduce_scatter(&[], &counts, &mut recv, "sum").is_ok() && recv.is_empty()
    });
    assert!(out.iter().all(|&ok| ok));
}

#[test]
fn min_max_identity_on_empty_blocks_is_not_skipped() {
    // Ops whose identity is not 0 (min: MAX, max: MIN) over a partition
    // with empty blocks: untouched regions must be *preserved*, reduced
    // regions exact — i.e. the executor never writes identity junk over
    // data and never skips a non-empty combine next to an empty one.
    for p in [2usize, 5] {
        let part = BlockPartition::from_counts(
            &(0..p).map(|g| if g % 2 == 0 { 3 } else { 0 }).collect::<Vec<_>>(),
        );
        let m = part.total();
        let inputs: Vec<Vec<i64>> =
            (0..p).map(|r| (0..m).map(|j| (r as i64 + 2) * (j as i64 + 1)).collect()).collect();
        let mut want = vec![i64::MAX; m];
        for v in &inputs {
            for (a, b) in want.iter_mut().zip(v) {
                *a = (*a).min(*b);
            }
        }
        let sched = Algorithm::parse("ar").unwrap().schedule(p);
        for rendezvous in [true, false] {
            let op = circulant_collectives::ops::parse_native_typed::<i64>("min").unwrap();
            let out = run_schedule_threads_tiered_typed::<i64>(
                &sched,
                &part,
                Arc::from(op),
                inputs.clone(),
                rendezvous,
            );
            for (r, (buf, _)) in out.iter().enumerate() {
                assert_eq!(buf, &want, "p={p} r={r} rdv={rendezvous}");
            }
        }
    }
}
