//! Exact-arithmetic dtype oracles (ISSUE 3): the integer dtypes use
//! wrapping ⊕, which is *exactly* associative and commutative — so every
//! schedule, every transport tier and every association of the reduction
//! must produce **bit-identical** results. No tolerances anywhere in this
//! file: every assertion is `==` on integer values.
//!
//! Three layers of oracle:
//!   1. pooled vs rendezvous tier bit-identity (i64/u64) over regular /
//!      random / zipf / degenerate single-block partitions;
//!   2. cross-generator identity: every schedule generator in the library
//!      executes bit-identically on both tiers, and every allreduce /
//!      reduce-scatter generator agrees exactly with the scalar wrapping
//!      fold;
//!   3. all four native ops (sum/prod/min/max) exact in every integer
//!      dtype end-to-end.

use std::sync::Arc;

use circulant_collectives::collectives::{
    baselines, run_schedule_threads_tiered_typed, run_schedule_threads_typed, Algorithm,
};
use circulant_collectives::datatypes::elem::{int_vec, test_value_bounds};
use circulant_collectives::datatypes::{BlockPartition, Elem};
use circulant_collectives::ops::{parse_native_typed, ReduceOp, SumOp};
use circulant_collectives::schedule::Schedule;
use circulant_collectives::topology::skips::SkipScheme;
use circulant_collectives::util::rng::SplitMix64;

fn inputs_for<T: Elem>(p: usize, m: usize, seed: u64) -> Vec<Vec<T>> {
    let (lo, hi) = test_value_bounds(T::DTYPE);
    let mut rng = SplitMix64::new(seed);
    (0..p).map(|_| int_vec(&mut rng, m, lo, hi)).collect()
}

/// Scalar fold of `op` over all rank inputs — exact for integer dtypes in
/// any association, so it is THE unique correct answer.
fn fold_oracle<T: Elem>(inputs: &[Vec<T>], op: &dyn ReduceOp<T>) -> Vec<T> {
    let mut acc = vec![op.identity(); inputs[0].len()];
    for v in inputs {
        op.combine(&mut acc, v);
    }
    acc
}

/// The partition shapes of the oracle matrix, for one (p, m).
fn partitions(p: usize, m: usize) -> Vec<(String, BlockPartition)> {
    let mut v = vec![
        ("regular".to_string(), BlockPartition::regular(p, m)),
        ("random".to_string(), BlockPartition::random(p, m, 60 + p as u64)),
        ("zipf".to_string(), BlockPartition::zipf(p, m, 1.3, p as u64)),
        ("single-block-0".to_string(), BlockPartition::single_block(p, m, 0)),
    ];
    if p > 1 {
        v.push(("single-block-last".to_string(), BlockPartition::single_block(p, m, p - 1)));
    }
    v
}

fn assert_cross_tier_identity<T: Elem>(seed: u64) {
    for p in [2usize, 5, 22] {
        let m = 7 * p + 3;
        for (wname, part) in partitions(p, m) {
            let inputs = inputs_for::<T>(p, part.total(), seed + p as u64);
            let want = fold_oracle::<T>(&inputs, &SumOp);
            for alg_name in ["rs", "ar"] {
                let sched = Algorithm::parse(alg_name).unwrap().schedule(p);
                let rdv = run_schedule_threads_tiered_typed::<T>(
                    &sched,
                    &part,
                    Arc::new(SumOp),
                    inputs.clone(),
                    true,
                );
                let pooled = run_schedule_threads_tiered_typed::<T>(
                    &sched,
                    &part,
                    Arc::new(SumOp),
                    inputs.clone(),
                    false,
                );
                for r in 0..p {
                    assert_eq!(
                        rdv[r].0, pooled[r].0,
                        "{:?} {wname} {alg_name} p={p} r={r}: tiers disagree",
                        T::DTYPE
                    );
                    // …and both match the unique exact answer on the
                    // region the collective's semantics define.
                    let range =
                        if alg_name == "ar" { 0..part.total() } else { part.range(r) };
                    assert_eq!(
                        &rdv[r].0[range.clone()],
                        &want[range],
                        "{:?} {wname} {alg_name} p={p} r={r}: wrong result",
                        T::DTYPE
                    );
                }
                assert!(
                    pooled.iter().all(|(_, c)| c.rendezvous_hits == 0),
                    "pooled run published"
                );
            }
        }
    }
}

#[test]
fn pooled_and_rendezvous_bit_identical_i64() {
    assert_cross_tier_identity::<i64>(17);
}

#[test]
fn pooled_and_rendezvous_bit_identical_u64() {
    assert_cross_tier_identity::<u64>(23);
}

/// Every schedule generator in the library, instantiated for `p` (rooted
/// generators at two roots; power-of-two-only generators gated).
fn all_generator_schedules(p: usize) -> Vec<Schedule> {
    let mut v = Vec::new();
    for scheme in [SkipScheme::HalvingUp, SkipScheme::PowerOfTwo, SkipScheme::Sqrt] {
        let skips = scheme.skips(p).unwrap();
        v.push(circulant_collectives::collectives::reduce_scatter_schedule(p, &skips));
        v.push(circulant_collectives::collectives::allgather_schedule(p, &skips));
        v.push(circulant_collectives::collectives::allreduce_schedule(p, &skips));
    }
    v.push(baselines::ring_reduce_scatter_schedule(p));
    v.push(baselines::ring_allgather_schedule(p));
    v.push(baselines::ring_allreduce_schedule(p));
    v.push(baselines::bruck_allgather_schedule(p));
    v.push(baselines::binomial_allreduce_schedule(p));
    v.push(baselines::rabenseifner_allreduce_schedule(p));
    // the documented rendezvous-unsafe generator: falls back per round
    v.push(baselines::recursive_doubling_allreduce_schedule(p));
    for root in [0, p - 1] {
        v.push(baselines::binomial_reduce_schedule(p, root));
        v.push(baselines::binomial_bcast_schedule(p, root));
        v.push(baselines::binomial_scatter_schedule(p, root));
        v.push(baselines::binomial_gather_schedule(p, root));
    }
    if p.is_power_of_two() {
        v.push(baselines::recursive_halving_rs_schedule(p));
        v.push(baselines::recursive_doubling_ag_schedule(p));
    }
    v
}

#[test]
fn every_generator_bit_identical_across_tiers_i64() {
    // Executing the SAME schedule on the rendezvous and pooled tiers must
    // be bit-for-bit indistinguishable, whatever the schedule computes —
    // the tier only changes where the ⊕ operand is read from, never the
    // value. Covers every generator, including the rendezvous-unsafe
    // recursive-doubling butterfly (per-round fallback).
    for p in [2usize, 5, 8, 22] {
        let part = BlockPartition::regular(p, 3 * p + 1);
        for sched in all_generator_schedules(p) {
            let inputs = inputs_for::<i64>(p, part.total(), 7 + p as u64);
            let rdv = run_schedule_threads_tiered_typed::<i64>(
                &sched,
                &part,
                Arc::new(SumOp),
                inputs.clone(),
                true,
            );
            let pooled = run_schedule_threads_tiered_typed::<i64>(
                &sched,
                &part,
                Arc::new(SumOp),
                inputs,
                false,
            );
            for r in 0..p {
                assert_eq!(
                    rdv[r].0, pooled[r].0,
                    "{} p={p} r={r}: tiers disagree",
                    sched.name
                );
            }
        }
    }
}

#[test]
fn allreduce_generators_agree_exactly_i64() {
    // Wrapping ⊕ has a unique answer: every allreduce generator (circulant
    // under all three schemes, ring, recursive doubling, Rabenseifner,
    // binomial) must replicate exactly that vector on every rank.
    for p in [2usize, 5, 22] {
        let part = BlockPartition::regular(p, 4 * p + 3);
        let inputs = inputs_for::<i64>(p, part.total(), 300 + p as u64);
        let want = fold_oracle::<i64>(&inputs, &SumOp);
        let mut algs = Algorithm::allreduce_family();
        algs.push(Algorithm::parse("ar:pow2").unwrap());
        algs.push(Algorithm::parse("ar:sqrt").unwrap());
        for alg in algs {
            let sched = alg.schedule(p);
            let out = run_schedule_threads_typed::<i64>(
                &sched,
                &part,
                Arc::new(SumOp),
                inputs.clone(),
            );
            for (r, buf) in out.iter().enumerate() {
                assert_eq!(buf, &want, "{} p={p} r={r}", alg.name());
            }
        }
    }
}

#[test]
fn reduce_scatter_generators_agree_exactly_u64() {
    for p in [2usize, 5, 8, 22] {
        let part = BlockPartition::regular(p, 5 * p + 1);
        let inputs = inputs_for::<u64>(p, part.total(), 500 + p as u64);
        let want = fold_oracle::<u64>(&inputs, &SumOp);
        let mut algs = vec![
            Algorithm::parse("rs").unwrap(),
            Algorithm::parse("rs:pow2").unwrap(),
            Algorithm::parse("rs:sqrt").unwrap(),
            Algorithm::parse("ring-rs").unwrap(),
        ];
        if p.is_power_of_two() {
            algs.push(Algorithm::parse("rec-halving-rs").unwrap());
        }
        for alg in algs {
            let sched = alg.schedule(p);
            let out = run_schedule_threads_typed::<u64>(
                &sched,
                &part,
                Arc::new(SumOp),
                inputs.clone(),
            );
            for (r, buf) in out.iter().enumerate() {
                let range = part.range(r);
                assert_eq!(
                    &buf[range.clone()],
                    &want[range],
                    "{} p={p} r={r}",
                    alg.name()
                );
            }
        }
    }
}

fn assert_all_ops_exact<T: Elem>(seed: u64) {
    let p = 5usize;
    let part = BlockPartition::regular(p, 31);
    let sched = Algorithm::parse("ar").unwrap().schedule(p);
    for name in ["sum", "prod", "min", "max"] {
        let op: Arc<dyn ReduceOp<T>> = Arc::from(parse_native_typed::<T>(name).unwrap());
        let inputs = inputs_for::<T>(p, part.total(), seed);
        let want = fold_oracle::<T>(&inputs, op.as_ref());
        let out = run_schedule_threads_typed::<T>(&sched, &part, op, inputs);
        for (r, buf) in out.iter().enumerate() {
            assert_eq!(buf, &want, "{name} {:?} r={r}", T::DTYPE);
        }
    }
}

#[test]
fn all_native_ops_exact_in_every_integer_dtype() {
    assert_all_ops_exact::<i32>(41);
    assert_all_ops_exact::<i64>(42);
    assert_all_ops_exact::<u64>(43);
}

#[test]
fn float_dtypes_exact_on_small_integer_data() {
    // f32/f64 with small-integer-valued data stay exactly representable,
    // so even the float dtypes verify with == here (the general float
    // caveat — non-associative ⊕ — needs values that actually round).
    assert_cross_tier_identity::<f32>(71);
    assert_cross_tier_identity::<f64>(72);
}
