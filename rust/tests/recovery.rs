//! Self-healing integration tests: kill a rank mid-soak and drive the
//! engine's reconfiguration round end to end — RankDown on the in-flight
//! op, `recover()` within the 2×op-timeout hang bound, a dense remap
//! over the survivors, a bumped generation epoch, and ≥100 bit-exact
//! post-recovery ops against a fresh p−1 oracle. Covers the thread and
//! UDS backends for p ∈ {3, 5, 8}, the flap (transient death) case that
//! must NOT bump the generation, drain-mode shutdown racing a
//! reconfiguration, and a real 4-process `ccoll launch --launch.recover`
//! run where the survivors of a SIGKILL re-form and exit zero.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use circulant_collectives::collectives::CollectiveError;
use circulant_collectives::datatypes::{elem, Elem};
use circulant_collectives::engine::{CollectiveEngine, EngineConfig, EngineError, OpRequest};
use circulant_collectives::ops::SumOp;
use circulant_collectives::transport::fault::{FaultPlan, FaultTransport};
use circulant_collectives::transport::uds::uds_network_typed;
use circulant_collectives::transport::{network_typed, Endpoint, Transport};
use circulant_collectives::util::rng::SplitMix64;

type FaultNet = FaultTransport<i64, Endpoint<i64>>;

/// Integer-valued inputs + exact scalar sum oracle.
fn sum_case(p: usize, m: usize, seed: u64) -> (Vec<Vec<i64>>, Vec<i64>) {
    let (lo, hi) = elem::test_value_bounds(<i64 as Elem>::DTYPE);
    let mut rng = SplitMix64::new(seed);
    let inputs: Vec<Vec<i64>> = (0..p).map(|_| elem::int_vec(&mut rng, m, lo, hi)).collect();
    let mut want = vec![0i64; m];
    for v in &inputs {
        SumOp.combine(&mut want, v);
    }
    (inputs, want)
}

fn fault_engine(p: usize, plan: &FaultPlan, cfg: EngineConfig) -> CollectiveEngine<i64, FaultNet> {
    let transports: Vec<FaultNet> = network_typed::<i64>(p)
        .into_iter()
        .map(|ep| FaultTransport::new(ep, plan.clone()))
        .collect();
    CollectiveEngine::with_transports(cfg, transports)
}

fn scratch(tag: &str, p: usize) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "ccoll-recovery-{tag}-{p}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create scratch dir");
    dir
}

fn assert_rank_down(err: &EngineError, want_peer: usize, ctx: &str) {
    match err {
        EngineError::Collective {
            source: CollectiveError::RankDown { peer, .. },
            ..
        } => assert_eq!(
            *peer, want_peer,
            "{ctx}: RankDown names peer {peer}, want the killed rank {want_peer}"
        ),
        other => panic!("{ctx}: want CollectiveError::RankDown, got: {other}"),
    }
}

/// The full kill → detect → recover → resume contract, generic over the
/// wrapped backend. The fault plan must kill `killed` from op epoch 3.
fn kill_recover_resume<C>(
    mut engine: CollectiveEngine<i64, C>,
    p: usize,
    killed: usize,
    op_timeout: Duration,
    ctx: &str,
) where
    C: Transport<i64> + Send + 'static,
{
    // Ops 1 and 2 predate the kill epoch: bit-exact at full p.
    for i in 0..2u64 {
        let (inputs, want) = sum_case(p, 48, 7_000 + i);
        let out = engine
            .submit(OpRequest::allreduce(inputs, "sum"))
            .unwrap()
            .wait()
            .unwrap_or_else(|e| panic!("{ctx}: pre-kill op {} must survive: {e}", i + 1));
        for (r, buf) in out.iter().enumerate() {
            assert_eq!(buf[..], want[..], "{ctx} rank {r}: pre-kill result diverges");
        }
    }
    // Op 3 trips the kill: the in-flight op fails with RankDown naming
    // the dead rank, inside the 2×op-timeout hang bound.
    let (inputs, _) = sum_case(p, 48, 7_100);
    let handle = engine.submit(OpRequest::allreduce(inputs, "sum")).unwrap();
    let t0 = Instant::now();
    let err = handle.wait().expect_err("op 3 needs the killed rank");
    assert!(
        t0.elapsed() < 2 * op_timeout,
        "{ctx}: failed wait took {:?}, over the 2×op-timeout hang bound",
        t0.elapsed()
    );
    assert_rank_down(&err, killed, &format!("{ctx} in-flight op"));

    // Reconfiguration: survivor consensus, dense remap, audited p−1
    // plans, bumped generation — all inside the same 2×op-timeout bound.
    let t_rec = Instant::now();
    let report = engine.recover().unwrap_or_else(|e| panic!("{ctx}: recover failed: {e}"));
    let took = t_rec.elapsed();
    assert!(
        took <= 2 * op_timeout,
        "{ctx}: reconfiguration took {took:?}, over the 2×op-timeout bound"
    );
    assert_eq!(report.p, p - 1, "{ctx}: survivor world size");
    assert_eq!(report.generation, 1, "{ctx}: first recovery is generation 1");
    assert_eq!(report.failed, vec![killed], "{ctx}: the census must name the killed rank");
    assert_eq!(engine.p(), p - 1);
    assert_eq!(engine.generation(), 1);
    assert_eq!(engine.recoveries(), 1);
    let want_live: Vec<usize> = (0..p).filter(|&r| r != killed).collect();
    assert_eq!(engine.live_ranks(), &want_live[..], "{ctx}: dense remap order");
    let health = engine.peer_health();
    assert_eq!(health.len(), p, "{ctx}: health bitmap spans the construction ranks");
    for (r, up) in health.iter().enumerate() {
        assert_eq!(*up, r != killed, "{ctx}: health bit for physical rank {r}");
    }

    // ≥100 post-recovery ops, each bit-exact against a fresh p−1
    // wrapping oracle — the survivor schedule is a first-class citizen.
    for i in 0..100u64 {
        let (inputs, want) = sum_case(p - 1, 32, 7_200 + i);
        let out = engine
            .submit(OpRequest::allreduce(inputs, "sum"))
            .unwrap_or_else(|e| panic!("{ctx}: post-recovery submit {i} refused: {e}"))
            .wait()
            .unwrap_or_else(|e| panic!("{ctx}: post-recovery op {i} failed: {e}"));
        for (r, buf) in out.iter().enumerate() {
            assert_eq!(
                buf[..],
                want[..],
                "{ctx} op {i} dense rank {r}: post-recovery result diverges from the \
                 p−1 oracle"
            );
        }
    }
    assert!(
        engine.recovered_ops() >= 100,
        "{ctx}: recovered_ops = {} after 100 completed post-recovery ops",
        engine.recovered_ops()
    );
    let deadline = Instant::now() + Duration::from_secs(2);
    while engine.in_flight() > 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_micros(200));
    }
    assert_eq!(engine.in_flight(), 0, "{ctx}: in-flight slots leaked across the recovery");
    engine.shutdown();
}

/// Thread backend: kill a middle rank (the dense remap has to shift the
/// tail down) mid-soak for p ∈ {3, 5, 8} and run the full contract.
#[test]
fn kill_recover_resume_thread() {
    for p in [3usize, 5, 8] {
        let killed = p / 2;
        let op_timeout = Duration::from_millis(500);
        let plan = FaultPlan::new(0x5E1F_4EA1).kill_rank(killed, 3);
        let engine = fault_engine(p, &plan, EngineConfig::new(p).op_timeout(op_timeout));
        kill_recover_resume(engine, p, killed, op_timeout, &format!("thread p={p}"));
    }
}

/// UDS backend: the same contract over a fault-wrapped socket mesh —
/// the generation bump must also engage the wire-level stale filter.
#[test]
fn kill_recover_resume_uds() {
    for p in [3usize, 5, 8] {
        let killed = p / 2;
        let op_timeout = Duration::from_millis(500);
        let dir = scratch("kill", p);
        let nets = uds_network_typed::<i64>(p, &dir).expect("uds bootstrap");
        let plan = FaultPlan::new(0x5E1F_0D5).kill_rank(killed, 3);
        let transports: Vec<_> =
            nets.into_iter().map(|t| FaultTransport::new(t, plan.clone())).collect();
        let engine = CollectiveEngine::<i64, _>::with_transports(
            EngineConfig::new(p).op_timeout(op_timeout),
            transports,
        );
        kill_recover_resume(engine, p, killed, op_timeout, &format!("uds p={p}"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// A flap (transient death that revives within the deadline) is NOT a
/// reconfiguration: ops inside the outage window fail RankDown naming
/// the flapped rank, ops after it complete bit-exact again, and the
/// generation epoch never moves.
#[test]
fn flap_recovers_without_generation_bump() {
    let p = 4;
    let flapped = 2;
    // Down for op epochs [3, 5): the fault plan revives the rank once
    // the per-endpoint op watermark clears the window.
    let plan = FaultPlan::new(0xF1A_9).flap_rank(flapped, 3, 2);
    let mut engine = fault_engine(
        p,
        &plan,
        EngineConfig::new(p).op_timeout(Duration::from_millis(400)),
    );
    // Ops 1 and 2 predate the outage.
    for i in 0..2u64 {
        let (inputs, want) = sum_case(p, 32, 8_000 + i);
        let out = engine
            .submit(OpRequest::allreduce(inputs, "sum"))
            .unwrap()
            .wait()
            .unwrap_or_else(|e| panic!("pre-flap op {} must survive: {e}", i + 1));
        for buf in &out {
            assert_eq!(buf[..], want[..], "pre-flap result diverges");
        }
    }
    // Serial ops across the outage. The exact boundary op is allowed to
    // fail either way (the worker's fast-fail check reads the health
    // snapshot from before the op advances the watermark), so assert the
    // shape, not the exact indices: some RankDowns naming the flapped
    // rank, then completions again — with no reconfiguration round.
    let mut rank_downs = 0usize;
    let mut resumed = false;
    for i in 0..12u64 {
        let (inputs, want) = sum_case(p, 32, 8_100 + i);
        match engine.submit(OpRequest::allreduce(inputs, "sum")).unwrap().wait() {
            Ok(out) => {
                for buf in &out {
                    assert_eq!(buf[..], want[..], "op {i}: flap changed a completed result");
                }
                if rank_downs > 0 {
                    resumed = true;
                    break;
                }
            }
            Err(err) => {
                assert_rank_down(&err, flapped, &format!("flap-window op {i}"));
                rank_downs += 1;
            }
        }
    }
    assert!(rank_downs >= 1, "the outage window must fail at least one op");
    assert!(resumed, "no op completed after the revival — the flap never healed");
    assert_eq!(engine.generation(), 0, "a flap must not bump the generation epoch");
    assert_eq!(engine.recoveries(), 0, "a flap must not count as a reconfiguration");
    let health = engine.peer_health();
    assert!(health.iter().all(|&up| up), "all ranks are live again after the revival");
    engine.shutdown();
}

/// Drain-mode shutdown racing a reconfiguration: recover, submit a
/// burst, drain immediately — nothing hangs, new work is refused, every
/// handle settles bit-exact, and no in-flight slot leaks.
#[test]
fn drain_shutdown_right_after_recover() {
    let p = 4;
    let killed = 1;
    let plan = FaultPlan::new(0xD4A1_9E4).kill_rank(killed, 2);
    let mut engine = fault_engine(
        p,
        &plan,
        EngineConfig::new(p).op_timeout(Duration::from_millis(400)),
    );
    let (inputs, want) = sum_case(p, 24, 9_000);
    let out = engine
        .submit(OpRequest::allreduce(inputs, "sum"))
        .unwrap()
        .wait()
        .expect("op 1 predates the kill epoch");
    for buf in &out {
        assert_eq!(buf[..], want[..], "pre-kill op must stay bit-exact");
    }
    let (inputs, _) = sum_case(p, 24, 9_001);
    let err = engine
        .submit(OpRequest::allreduce(inputs, "sum"))
        .unwrap()
        .wait()
        .expect_err("op 2 trips the kill");
    assert_rank_down(&err, killed, "pre-recovery kill victim");
    let report = engine.recover().expect("reconfiguration over the survivors");
    assert_eq!(report.p, p - 1);

    // A burst into the freshly re-formed engine, drained immediately.
    let mut pending = Vec::new();
    for i in 0..3u64 {
        let (inputs, want) = sum_case(p - 1, 24, 9_100 + i);
        pending.push((engine.submit(OpRequest::allreduce(inputs, "sum")).unwrap(), want));
    }
    let t0 = Instant::now();
    engine.drain_shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "drain across a reconfigured engine hung for {:?}",
        t0.elapsed()
    );
    let (inputs, _) = sum_case(p - 1, 24, 9_200);
    match engine.submit(OpRequest::allreduce(inputs, "sum")) {
        Err(EngineError::ShutDown) => {}
        Ok(_) => panic!("submit after drain_shutdown must be refused"),
        Err(other) => panic!("want ShutDown after drain, got: {other}"),
    }
    for (i, (handle, want)) in pending.into_iter().enumerate() {
        let out = handle
            .wait()
            .unwrap_or_else(|e| panic!("drained post-recovery op {i} must settle cleanly: {e}"));
        for buf in &out {
            assert_eq!(buf[..], want[..], "drained op {i} diverges from the p−1 oracle");
        }
    }
    assert_eq!(engine.in_flight(), 0, "drain left slots in flight");
}

/// A shut-down engine refuses reconfiguration (there is nothing left to
/// re-form) with the ShutDown taxonomy, not a panic or a hang.
#[test]
fn recover_after_shutdown_is_refused() {
    let p = 3;
    let plan = FaultPlan::new(0x5D_0B).kill_rank(1, 1);
    let mut engine = fault_engine(
        p,
        &plan,
        EngineConfig::new(p).op_timeout(Duration::from_millis(300)),
    );
    engine.shutdown();
    match engine.recover() {
        Err(EngineError::ShutDown) => {}
        Ok(_) => panic!("recover on a shut-down engine must be refused"),
        Err(other) => panic!("want ShutDown, got: {other}"),
    }
}

/// THE self-healing acceptance test: 4 real `ccoll launch` processes
/// over UDS with `--launch.recover`, SIGKILL one mid-soak — the three
/// survivors must detect the death (directly via PeerDown, or
/// indirectly via the health census after a tight recv timeout),
/// independently agree on the survivor set, re-form at generation 1,
/// run 50 more verified iterations, and exit ZERO.
#[test]
fn four_process_kill_one_rank_survivors_recover_and_exit_zero() {
    use std::process::{Command, Stdio};
    let bin = env!("CARGO_BIN_EXE_ccoll");
    let dir = scratch("proc", 4);
    let dir_s = dir.to_str().unwrap().to_string();
    let mut children: Vec<_> = (0..4)
        .map(|r| {
            Command::new(bin)
                .args([
                    "launch",
                    "--backend",
                    "uds",
                    "--rank",
                    &r.to_string(),
                    "--world",
                    "4",
                    "--dir",
                    &dir_s,
                    "--launch.m",
                    "4096",
                    "--launch.iters",
                    "1000000",
                    "--launch.verify",
                    "1",
                    "--launch.recover",
                    "1",
                    "--launch.recover_iters",
                    "50",
                    "--launch.timeout_ms",
                    "3000",
                ])
                .stdout(Stdio::null())
                .stderr(Stdio::null())
                .spawn()
                .expect("spawn ccoll launch")
        })
        .collect();
    // Let the mesh bootstrap and the soak begin, then SIGKILL rank 3 —
    // no graceful shutdown path runs.
    std::thread::sleep(Duration::from_millis(1500));
    children[3].kill().expect("kill rank 3");
    let _ = children[3].wait();

    // Budget: worst-case indirect detection costs one 3s recv timeout,
    // then the generation-1 bootstrap and 50 verified iterations.
    let deadline = Instant::now() + Duration::from_secs(60);
    let mut statuses: Vec<Option<std::process::ExitStatus>> = vec![None; 3];
    while Instant::now() < deadline && statuses.iter().any(Option::is_none) {
        for (r, slot) in statuses.iter_mut().enumerate() {
            if slot.is_none() {
                *slot = children[r].try_wait().expect("try_wait");
            }
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    // Reap anything still running before asserting, so a failure can't
    // strand processes.
    for c in children.iter_mut() {
        let _ = c.kill();
        let _ = c.wait();
    }
    let _ = std::fs::remove_dir_all(&dir);
    for (r, slot) in statuses.iter().enumerate() {
        let Some(status) = slot else {
            panic!(
                "rank {r} did not exit within 60s of rank 3's kill — \
                 the recovery hung or the survivor sets diverged"
            )
        };
        assert!(
            status.success(),
            "rank {r} exited {status} after the kill — survivors must re-form at \
             generation 1 and finish the recovery soak with exit 0"
        );
    }
}
