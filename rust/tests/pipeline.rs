//! Pipelined large-message tier: end-to-end equivalence and failure
//! semantics.
//!
//! The tier's one correctness claim is that chunked execution is
//! *invisible* except in time: a pipelined allreduce must be bit-identical
//! to the plain one-epoch schedule (and the scalar oracle) in the wrapping
//! integer dtypes, over both the thread and UDS backends, across regular
//! and zipf chunk partitions, at every chunk-geometry edge (m not
//! divisible by the chunk, chunk ≥ m degenerating to plain, zero-length
//! vectors) — and a killed rank must still surface as the bounded
//! `RankDown` fast-fail, not a hang, when the dying op is chunked.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use circulant_collectives::collectives::{
    allreduce_schedule, pipeline_chunk_sizes, CollectiveError, PipelinedCursor, Progress,
};
use circulant_collectives::datatypes::{elem, BlockPartition, Elem};
use circulant_collectives::engine::{CollectiveEngine, EngineConfig, EngineError, OpRequest};
use circulant_collectives::ops::SumOp;
use circulant_collectives::schedule::Plan;
use circulant_collectives::transport::fault::{FaultPlan, FaultTransport};
use circulant_collectives::transport::uds::uds_network_typed;
use circulant_collectives::transport::{
    network_typed, run_ranks_inputs_typed, Endpoint, Transport,
};
use circulant_collectives::util::rng::SplitMix64;

/// Integer-valued inputs + exact scalar sum oracle (wrapping ⊕, hence
/// exactly associative: any execution order is bit-identical).
fn sum_case<T: Elem>(p: usize, m: usize, seed: u64) -> (Vec<Vec<T>>, Vec<T>) {
    let (lo, hi) = elem::test_value_bounds(T::DTYPE);
    let mut rng = SplitMix64::new(seed);
    let inputs: Vec<Vec<T>> = (0..p).map(|_| elem::int_vec(&mut rng, m, lo, hi)).collect();
    let mut want = vec![T::zero(); m];
    for v in &inputs {
        SumOp.combine(&mut want, v);
    }
    (inputs, want)
}

/// One allreduce through `engine`, asserted bit-exact on every rank.
fn run_one<T: Elem>(
    engine: &mut CollectiveEngine<T>,
    inputs: &[Vec<T>],
    want: &[T],
    ctx: &str,
) {
    let out = engine
        .submit(OpRequest::allreduce(inputs.to_vec(), "sum"))
        .unwrap()
        .wait()
        .unwrap_or_else(|e| panic!("{ctx}: op failed: {e}"));
    for (r, buf) in out.iter().enumerate() {
        assert!(buf[..] == want[..], "{ctx} rank {r}: result is not bit-identical");
    }
}

fn scratch(tag: &str, p: usize) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("ccoll-pipeline-{tag}-{p}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create scratch dir");
    dir
}

/// Pipelined ≡ plain ≡ oracle over the thread backend (the copy tiers +
/// rendezvous path), i64, p ∈ {2, 5, 8}, m deliberately not divisible by
/// the chunk so the remainder folds into the last chunk.
#[test]
fn pipelined_matches_plain_and_oracle_thread_i64() {
    for p in [2usize, 5, 8] {
        let m = 1031; // prime: never divisible by the 64-element chunk
        let chunk_bytes = 64 * std::mem::size_of::<i64>();
        assert!(pipeline_chunk_sizes(m, 64).len() > 1, "geometry must actually chunk");
        let (inputs, want) = sum_case::<i64>(p, m, 0x91_0000 + p as u64);

        let mut plain: CollectiveEngine<i64> =
            CollectiveEngine::new(EngineConfig::new(p).pipeline_min_bytes(0));
        for i in 0..3 {
            run_one(&mut plain, &inputs, &want, &format!("plain p={p} op {i}"));
        }
        assert_eq!(plain.fusion_stats().pipelined_ops, 0, "p={p}: disabled tier chunked an op");
        plain.shutdown();

        let mut piped: CollectiveEngine<i64> = CollectiveEngine::new(
            EngineConfig::new(p).pipeline_min_bytes(1).pipeline_chunk_bytes(chunk_bytes),
        );
        for i in 0..3 {
            run_one(&mut piped, &inputs, &want, &format!("pipelined p={p} op {i}"));
        }
        assert_eq!(piped.fusion_stats().pipelined_ops, 3, "p={p}: ops were not pipelined");
        piped.shutdown();
    }
}

/// Same equivalence in the second wrapping integer dtype (u64), with a
/// bit pattern (rank in the high word) that would expose any chunk
/// misrouting immediately.
#[test]
fn pipelined_matches_plain_and_oracle_thread_u64() {
    for p in [2usize, 5, 8] {
        let m = 777;
        let inputs: Vec<Vec<u64>> =
            (0..p).map(|r| (0..m).map(|j| (r as u64) << 32 | j as u64).collect()).collect();
        let mut want = vec![0u64; m];
        for v in &inputs {
            for (a, x) in want.iter_mut().zip(v) {
                *a = a.wrapping_add(*x);
            }
        }
        let mut piped: CollectiveEngine<u64> = CollectiveEngine::new(
            EngineConfig::new(p)
                .pipeline_min_bytes(1)
                .pipeline_chunk_bytes(100 * std::mem::size_of::<u64>()),
        );
        run_one(&mut piped, &inputs, &want, &format!("pipelined u64 p={p}"));
        assert_eq!(piped.fusion_stats().pipelined_ops, 1);
        piped.shutdown();
    }
}

/// The pooled degrade: UDS endpoints advertise no rendezvous caps, so
/// every chunk epoch runs on the pooled copy tier — same bits, p ∈
/// {2, 5, 8}, engine wired over real sockets.
#[test]
fn uds_pipelined_runs_pooled_bit_identical() {
    for p in [2usize, 5, 8] {
        let dir = scratch("pooled", p);
        let nets = uds_network_typed::<i64>(p, &dir).expect("uds bootstrap");
        let mut engine = CollectiveEngine::<i64, _>::with_transports(
            EngineConfig::new(p)
                .pipeline_min_bytes(1)
                .pipeline_chunk_bytes(32 * std::mem::size_of::<i64>()),
            nets,
        );
        for i in 0..2u64 {
            let (inputs, want) = sum_case::<i64>(p, 257, 0x0D5_100 + i);
            let out = engine
                .submit(OpRequest::allreduce(inputs, "sum"))
                .unwrap()
                .wait()
                .unwrap_or_else(|e| panic!("uds p={p} op {i}: {e}"));
            for (r, buf) in out.iter().enumerate() {
                assert!(buf[..] == want[..], "uds p={p} rank {r}: pooled chunking diverged");
            }
        }
        assert_eq!(engine.fusion_stats().pipelined_ops, 2, "uds p={p}: ops were not pipelined");
        engine.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Chunk partitions need not be regular: drive a [`PipelinedCursor`]
/// directly whose chunks carry *zipf* block partitions (what the engine
/// never emits, but the cursor contract allows — any partition per chunk,
/// one schedule shape). Non-blocking polling on every rank, so the
/// sliding window actually interleaves chunk epochs.
#[test]
fn zipf_chunk_partitions_through_the_raw_cursor() {
    for p in [2usize, 5, 8] {
        let skips = circulant_collectives::topology::skips::SkipScheme::HalvingUp
            .skips(p)
            .unwrap();
        let sched = allreduce_schedule(p, &skips);
        let chunk_lens = [37usize, 41, 29];
        let m: usize = chunk_lens.iter().sum();
        let mut chunks: Vec<(usize, Arc<Plan>)> = Vec::new();
        let mut offset = 0usize;
        for (k, &len) in chunk_lens.iter().enumerate() {
            let part = BlockPartition::zipf(p, len, 1.2, 0x21F + k as u64);
            assert_eq!(part.total(), len);
            chunks.push((offset, Arc::new(Plan::new(sched.clone(), part))));
            offset += len;
        }
        let (inputs, want) = sum_case::<i64>(p, m, 0x21F0 + p as u64);
        let chunks2 = chunks.clone();
        let outs = run_ranks_inputs_typed::<i64, _, _, _>(inputs, move |_rank, ep, mut buf| {
            let mut cur = PipelinedCursor::new(7, chunks2.clone(), 2);
            assert_eq!(cur.num_chunks(), 3);
            loop {
                match cur.step(ep, &SumOp, &mut buf, false).unwrap() {
                    Progress::Done => break,
                    Progress::Pending => std::thread::yield_now(),
                }
            }
            let _ = ep.finish_op(7);
            buf
        });
        for (r, buf) in outs.iter().enumerate() {
            assert!(buf[..] == want[..], "p={p} rank {r}: zipf-chunked result diverged");
        }
    }
}

/// Geometry edges through the engine: a chunk as large as the payload
/// (or larger, or zero-sized in elements) must fall back to the plain
/// path — correct result, pipelined-op counter untouched.
#[test]
fn chunk_edges_degrade_to_plain() {
    let p = 4;
    // chunk ≥ m: one chunk is no pipeline.
    let (inputs, want) = sum_case::<i64>(p, 64, 0xED6E_1);
    let mut engine: CollectiveEngine<i64> = CollectiveEngine::new(
        EngineConfig::new(p)
            .pipeline_min_bytes(1)
            .pipeline_chunk_bytes(64 * std::mem::size_of::<i64>()),
    );
    run_one(&mut engine, &inputs, &want, "chunk == m");
    // chunk_bytes below one element: chunk_elems == 0 disables chunking.
    let mut tiny: CollectiveEngine<i64> = CollectiveEngine::new(
        EngineConfig::new(p).pipeline_min_bytes(1).pipeline_chunk_bytes(4),
    );
    run_one(&mut tiny, &inputs, &want, "chunk < one element");
    assert_eq!(engine.fusion_stats().pipelined_ops, 0, "chunk == m must run plain");
    assert_eq!(tiny.fusion_stats().pipelined_ops, 0, "sub-element chunk must run plain");
    engine.shutdown();
    tiny.shutdown();

    // Zero-length working vector: below every threshold, still correct.
    let mut empty: CollectiveEngine<i64> = CollectiveEngine::new(
        EngineConfig::new(p).pipeline_min_bytes(1).pipeline_chunk_bytes(64),
    );
    let inputs: Vec<Vec<i64>> = (0..p).map(|_| Vec::new()).collect();
    let out = empty.submit(OpRequest::allreduce(inputs, "sum")).unwrap().wait().unwrap();
    assert!(out.iter().all(|b| b.is_empty()), "zero-length allreduce must return empty");
    assert_eq!(empty.fusion_stats().pipelined_ops, 0);
    empty.shutdown();

    // And the geometry helper itself at the edges.
    assert_eq!(pipeline_chunk_sizes(64, 64), vec![64]);
    assert_eq!(pipeline_chunk_sizes(64, 0), vec![64]);
    assert_eq!(pipeline_chunk_sizes(127, 64), vec![127], "m < 2·chunk folds to plain");
    assert_eq!(pipeline_chunk_sizes(130, 64), vec![64, 66], "remainder folds into the last");
}

fn assert_rank_down(err: &EngineError, want_peer: usize, ctx: &str) {
    match err {
        EngineError::Collective { source: CollectiveError::RankDown { peer, .. }, .. } => {
            assert_eq!(
                *peer, want_peer,
                "{ctx}: RankDown names peer {peer}, want the killed rank {want_peer}"
            )
        }
        other => panic!("{ctx}: want CollectiveError::RankDown, got: {other}"),
    }
}

/// Chaos over the chunked path: kill one rank mid-soak with the tier
/// forced on (8-element chunk epochs, 8 chunks per op, window in play).
/// Pre-kill pipelined ops stay bit-exact; from the kill epoch on, every
/// wait fails `RankDown` naming the dead rank inside the 2×op-timeout
/// fast-fail bound — the pipelined driver's aggregate progress stamp and
/// down-peer scan must be as live as the plain cursor's.
#[test]
fn kill_one_rank_pipelined_rank_down_fast_fail() {
    for p in [2usize, 5, 8] {
        let killed = p - 1;
        let m = 64;
        let plan = FaultPlan::new(0xBAD5_EED9).kill_rank(killed, 3);
        let transports: Vec<FaultTransport<i64, Endpoint<i64>>> = network_typed::<i64>(p)
            .into_iter()
            .map(|ep| FaultTransport::new(ep, plan.clone()))
            .collect();
        let mut engine = CollectiveEngine::with_transports(
            EngineConfig::new(p)
                .pipeline_min_bytes(1)
                .pipeline_chunk_bytes(8 * std::mem::size_of::<i64>())
                .op_timeout(Duration::from_millis(400)),
            transports,
        );
        // Ops 1 and 2 predate the kill epoch: chunked and bit-exact.
        for i in 0..2u64 {
            let (inputs, want) = sum_case::<i64>(p, m, 0xC4_0 + i);
            run_one(&mut engine, &inputs, &want, &format!("p={p} pre-kill op {}", i + 1));
        }
        assert_eq!(engine.fusion_stats().pipelined_ops, 2, "p={p}: soak ops must be chunked");
        // From op 3 on, rank p−1 is dead: RankDown, bounded.
        for i in 0..2u64 {
            let (inputs, _) = sum_case::<i64>(p, m, 0xC4_8 + i);
            let handle = engine.submit(OpRequest::allreduce(inputs, "sum")).unwrap();
            let t0 = Instant::now();
            let err = handle.wait().expect_err("chunked op past the kill epoch must fail");
            let waited = t0.elapsed();
            assert!(
                waited < Duration::from_millis(800),
                "p={p}: chunked fast-fail took {waited:?}, over the 2×op-timeout bound"
            );
            assert_rank_down(&err, killed, &format!("p={p} post-kill chunked op {}", i + 3));
        }
        engine.shutdown();
    }
}
