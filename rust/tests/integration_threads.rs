//! Integration tests: every executable algorithm on the thread network,
//! against scalar oracles, across operators, partitions and p — plus
//! failure injection and concurrency stress.

use std::sync::Arc;

use circulant_collectives::collectives::{run_schedule_threads, Algorithm};
use circulant_collectives::coordinator::{Launcher, OpBackend};
use circulant_collectives::datatypes::BlockPartition;
use circulant_collectives::ops::{parse_native, ReduceOp};
use circulant_collectives::topology::skips::SkipScheme;
use circulant_collectives::util::rng::SplitMix64;

fn oracle(inputs: &[Vec<f32>], op: &dyn ReduceOp) -> Vec<f32> {
    let mut acc = inputs[0].clone();
    for v in &inputs[1..] {
        op.combine(&mut acc, v);
    }
    acc
}

/// Exact-friendly inputs per op (integer-valued for sum; positive small
/// range for prod; anything for min/max).
fn inputs_for(op: &str, p: usize, m: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = SplitMix64::new(seed);
    (0..p)
        .map(|_| match op {
            "sum" => rng.int_valued_vec(m, -9, 10),
            "prod" => rng.int_valued_vec(m, 1, 3),
            _ => rng.normal_vec(m),
        })
        .collect()
}

#[test]
fn every_allreduce_algorithm_every_op() {
    for alg in Algorithm::allreduce_family() {
        for op_name in ["sum", "prod", "min", "max"] {
            for p in [2usize, 3, 7, 8] {
                // prod folds must associate exactly: use small integers
                let m = 2 * p + 3;
                let part = BlockPartition::regular(p, m);
                let inputs = inputs_for(op_name, p, m, (p * 31) as u64);
                let op = parse_native(op_name).unwrap();
                let want = oracle(&inputs, op.as_ref());
                let op: Arc<dyn ReduceOp> = Arc::from(op);
                let out = run_schedule_threads(&alg.schedule(p), &part, op, inputs);
                for (r, buf) in out.iter().enumerate() {
                    assert_eq!(buf, &want, "{} op={op_name} p={p} r={r}", alg.name());
                }
            }
        }
    }
}

#[test]
fn reduce_scatter_family_on_irregular_partitions() {
    for p in [2usize, 5, 9, 16] {
        for (wname, part) in [
            ("random", BlockPartition::random(p, 7 * p + 1, p as u64)),
            ("zipf", BlockPartition::zipf(p, 11 * p, 1.2, p as u64)),
            ("single", BlockPartition::single_block(p, 53, p - 1)),
            ("empty-some", {
                let mut counts = vec![3usize; p];
                counts[0] = 0;
                if p > 2 {
                    counts[2] = 0;
                }
                BlockPartition::from_counts(&counts)
            }),
        ] {
            let inputs = inputs_for("sum", p, part.total(), 7);
            let op = parse_native("sum").unwrap();
            let want = oracle(&inputs, op.as_ref());
            let sched = Algorithm::parse("rs").unwrap().schedule(p);
            let out = run_schedule_threads(&sched, &part, Arc::new(circulant_collectives::ops::SumOp), inputs);
            for (r, buf) in out.iter().enumerate() {
                assert_eq!(
                    &buf[part.range(r)],
                    &want[part.range(r)],
                    "{wname} p={p} r={r}"
                );
            }
        }
    }
}

#[test]
fn executor_oracle_irregular_and_degenerate_partitions() {
    // The ISSUE-1 sweep: reduce-scatter AND allreduce over random, zipf
    // and degenerate single-block (zero-size blocks) partitions for
    // p ∈ {2, 5, 22}, against the scalar oracle.
    for p in [2usize, 5, 22] {
        let parts = vec![
            ("random", BlockPartition::random(p, 5 * p + 3, 40 + p as u64)),
            ("zipf", BlockPartition::zipf(p, 9 * p, 1.4, p as u64)),
            ("single-block-0", BlockPartition::single_block(p, 37, 0)),
            ("single-block-last", BlockPartition::single_block(p, 29, p - 1)),
        ];
        for (wname, part) in parts {
            let inputs = inputs_for("sum", p, part.total(), 13 + p as u64);
            let op = parse_native("sum").unwrap();
            let want = oracle(&inputs, op.as_ref());
            for alg_name in ["rs", "ar"] {
                let alg = Algorithm::parse(alg_name).unwrap();
                let out = run_schedule_threads(
                    &alg.schedule(p),
                    &part,
                    Arc::new(circulant_collectives::ops::SumOp),
                    inputs.clone(),
                );
                for (r, buf) in out.iter().enumerate() {
                    if alg.is_allreduce() {
                        assert_eq!(buf, &want, "{wname} {alg_name} p={p} r={r}");
                    } else {
                        assert_eq!(
                            &buf[part.range(r)],
                            &want[part.range(r)],
                            "{wname} {alg_name} p={p} r={r}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn pool_counters_account_for_every_send() {
    // The with-counters driver exposes pool statistics: every payload
    // comes from acquire(), so hits+misses must equal messages sent.
    // (The steady-state zero-miss property needs a persistent network
    // across collectives and is asserted in exec.rs's unit tests.)
    use circulant_collectives::collectives::run_schedule_threads_with_counters;
    let p = 8usize;
    let part = BlockPartition::regular(p, 4 * p);
    let alg = Algorithm::parse("ar").unwrap();
    let inputs = inputs_for("sum", p, part.total(), 3);
    let out = run_schedule_threads_with_counters(
        &alg.schedule(p),
        &part,
        Arc::new(circulant_collectives::ops::SumOp),
        inputs,
    );
    for (r, (_, c)) in out.iter().enumerate() {
        assert_eq!(c.pool_hits + c.pool_misses, c.msgs_sent, "rank {r}");
    }
}

#[test]
fn all_skip_schemes_execute_correctly() {
    for scheme in ["halving", "pow2", "sqrt", "full"] {
        for p in [2usize, 6, 22] {
            let alg = Algorithm::parse(&format!("ar:{scheme}")).unwrap();
            let m = 3 * p;
            let part = BlockPartition::regular(p, m);
            let inputs = inputs_for("sum", p, m, 3);
            let op = parse_native("sum").unwrap();
            let want = oracle(&inputs, op.as_ref());
            let out = run_schedule_threads(
                &alg.schedule(p),
                &part,
                Arc::new(circulant_collectives::ops::SumOp),
                inputs,
            );
            for buf in out {
                assert_eq!(buf, want, "{scheme} p={p}");
            }
        }
    }
}

#[test]
fn communicator_sequences_many_collectives() {
    // Stress tag isolation: 20 interleaved collectives per rank.
    let p = 6;
    let out = Launcher::new(p).run(move |mut comm| {
        let mut checksum = 0.0f64;
        for it in 0..20 {
            match it % 4 {
                0 => {
                    let mut v = vec![(comm.rank() + it) as f32; 8];
                    comm.allreduce(&mut v, "sum").unwrap();
                    checksum += v[0] as f64;
                }
                1 => {
                    let send: Vec<f32> = (0..p * 2).map(|j| j as f32).collect();
                    let mut recv = vec![0.0f32; 2];
                    comm.reduce_scatter_block(&send, &mut recv, "max").unwrap();
                    checksum += recv[0] as f64;
                }
                2 => {
                    let mine = vec![comm.rank() as f32];
                    let mut all = vec![0.0f32; p];
                    comm.allgather(&mine, &mut all).unwrap();
                    checksum += all[p - 1] as f64;
                }
                _ => {
                    let mut v = vec![1.0f32; 4];
                    comm.reduce(&mut v, it % p, "sum").unwrap();
                    comm.barrier().unwrap();
                    checksum += v[0] as f64;
                }
            }
        }
        checksum
    });
    // All ranks see identical allreduce/allgather contributions; the only
    // rank-dependent term is the reduce result at roots vs non-roots, so
    // just assert determinism across two runs.
    let out2 = Launcher::new(p).run(move |mut comm| {
        let mut checksum = 0.0f64;
        for it in 0..20 {
            match it % 4 {
                0 => {
                    let mut v = vec![(comm.rank() + it) as f32; 8];
                    comm.allreduce(&mut v, "sum").unwrap();
                    checksum += v[0] as f64;
                }
                1 => {
                    let send: Vec<f32> = (0..p * 2).map(|j| j as f32).collect();
                    let mut recv = vec![0.0f32; 2];
                    comm.reduce_scatter_block(&send, &mut recv, "max").unwrap();
                    checksum += recv[0] as f64;
                }
                2 => {
                    let mine = vec![comm.rank() as f32];
                    let mut all = vec![0.0f32; p];
                    comm.allgather(&mine, &mut all).unwrap();
                    checksum += all[p - 1] as f64;
                }
                _ => {
                    let mut v = vec![1.0f32; 4];
                    comm.reduce(&mut v, it % p, "sum").unwrap();
                    comm.barrier().unwrap();
                    checksum += v[0] as f64;
                }
            }
        }
        checksum
    });
    assert_eq!(out, out2, "collective sequence must be deterministic");
}

#[test]
fn dead_peer_detected_by_timeout() {
    // Rank 1 exits immediately; the others' allreduce must error out, not
    // hang (failure injection for the transport layer).
    use circulant_collectives::collectives::execute_rank;
    use circulant_collectives::ops::SumOp;
    let p = 4;
    let part = BlockPartition::regular(p, 8);
    let sched = Algorithm::parse("ar").unwrap().schedule(p);
    let part2 = Arc::new(part);
    let sched2 = Arc::new(sched);
    let out = circulant_collectives::transport::run_ranks(p, move |rank, ep| {
        if rank == 1 {
            return true; // dies silently
        }
        ep.timeout = std::time::Duration::from_millis(200);
        let mut buf = vec![0.0f32; part2.total()];
        execute_rank(ep, &sched2, &part2, &SumOp, &mut buf, 0).is_err()
    });
    // every surviving rank either errored directly or was downstream of
    // the dead rank; at least the direct neighbors must error
    assert!(out[0] || out[2] || out[3], "no rank noticed the dead peer");
}

#[test]
fn large_p_smoke() {
    // 64 threads on one core still completes promptly (channels, no spin).
    let p = 64;
    let part = BlockPartition::regular(p, p);
    let inputs = inputs_for("sum", p, p, 11);
    let op = parse_native("sum").unwrap();
    let want = oracle(&inputs, op.as_ref());
    let out = run_schedule_threads(
        &Algorithm::parse("ar").unwrap().schedule(p),
        &part,
        Arc::new(circulant_collectives::ops::SumOp),
        inputs,
    );
    for buf in out {
        assert_eq!(buf, want);
    }
}

#[test]
fn native_and_scheme_cross_product_reduce_scatter_counts() {
    // Transport counters must equal schedule-derived counters exactly.
    let p = 22;
    let m = 44;
    let part = BlockPartition::regular(p, m);
    let alg = Algorithm::parse("rs").unwrap();
    let sched = alg.schedule(p);
    let expected = sched.counters(&part);
    let part2 = Arc::new(part);
    let sched2 = Arc::new(sched);
    let out = circulant_collectives::transport::run_ranks(p, move |rank, ep| {
        let mut buf = vec![1.0f32; part2.total()];
        circulant_collectives::collectives::execute_rank(
            ep,
            &sched2,
            &part2,
            &circulant_collectives::ops::SumOp,
            &mut buf,
            0,
        )
        .unwrap();
        (rank, ep.counters.clone())
    });
    for (rank, c) in out {
        assert_eq!(c.elems_sent as usize, expected[rank].elems_sent);
        assert_eq!(c.elems_recv as usize, expected[rank].elems_recv);
        assert_eq!(c.sendrecv_rounds as usize, expected[rank].active_rounds);
    }
}

#[test]
fn scheme_from_launcher_is_honored() {
    // Fully-connected scheme via Launcher: p−1 rounds observed.
    let p = 9;
    let out = Launcher::new(p).scheme(SkipScheme::FullyConnected).run(move |mut comm| {
        let mut v = vec![1.0f32; p];
        comm.allreduce(&mut v, "sum").unwrap();
        (v[0], comm.counters().sendrecv_rounds)
    });
    for (val, rounds) in out {
        assert_eq!(val, p as f32);
        assert_eq!(rounds as usize, 2 * (p - 1));
    }
}

#[test]
fn native_backend_matches_default() {
    let p = 4;
    let out = Launcher::new(p).backend(OpBackend::Native).run(move |mut comm| {
        let mut v = vec![comm.rank() as f32 + 1.0; 5];
        comm.allreduce(&mut v, "prod").unwrap();
        v[0]
    });
    for x in out {
        assert_eq!(x, 24.0); // 1·2·3·4
    }
}
