//! PJRT runtime integration: the Rust↔XLA↔Pallas bridge, end to end.
//!
//! Requires `make artifacts` (skipped with a message otherwise, so
//! `cargo test` stays green on a fresh checkout).

use circulant_collectives::coordinator::{Launcher, OpBackend};
use circulant_collectives::ops::{parse_native, ReduceOp};
use circulant_collectives::runtime::{default_artifact_dir, ComputeService, Engine, Manifest};
use circulant_collectives::util::rng::SplitMix64;

fn artifacts_available() -> bool {
    Manifest::load(default_artifact_dir()).is_ok()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            return;
        }
    };
}

#[test]
fn engine_loads_and_compiles_all_ops() {
    require_artifacts!();
    let engine = Engine::load(default_artifact_dir()).unwrap();
    assert!(engine.platform().to_lowercase().contains("cpu") || !engine.platform().is_empty());
    let compiled = engine.warmup(&["sum", "prod", "min", "max"], true, true).unwrap();
    assert!(compiled >= 4, "expected at least one bucket per op, got {compiled}");
}

#[test]
fn pjrt_combine_matches_native_all_ops_and_sizes() {
    require_artifacts!();
    let engine = Engine::load(default_artifact_dir()).unwrap();
    let mut rng = SplitMix64::new(21);
    // exact bucket, sub-bucket (pad), over-bucket (chunk), tiny, odd sizes
    let sizes = [1usize, 5, 1000, 1024, 1025, 8192, 10_000, 300_000];
    for op_name in ["sum", "prod", "min", "max"] {
        let native = parse_native(op_name).unwrap();
        for &n in &sizes {
            let a0: Vec<f32> = if op_name == "prod" {
                rng.int_valued_vec(n, 1, 3)
            } else {
                rng.normal_vec(n)
            };
            let b: Vec<f32> = if op_name == "prod" {
                rng.int_valued_vec(n, 1, 3)
            } else {
                rng.normal_vec(n)
            };
            let mut want = a0.clone();
            native.combine(&mut want, &b);
            let mut got = a0.clone();
            engine
                .combine_into(op_name, &mut got, &b, native.identity())
                .unwrap_or_else(|e| panic!("{op_name} n={n}: {e}"));
            assert_eq!(got, want, "{op_name} n={n} (exactness: same f32 ops)");
        }
    }
}

#[test]
fn pjrt_combine_scaled_matches_fma() {
    require_artifacts!();
    let engine = Engine::load(default_artifact_dir()).unwrap();
    let mut rng = SplitMix64::new(22);
    for &n in &[7usize, 1024, 5000] {
        let r0 = rng.normal_vec(n);
        let t = rng.normal_vec(n);
        let scale = 0.25f32;
        let mut got = r0.clone();
        engine.combine_scaled_into(&mut got, &t, scale).unwrap();
        for i in 0..n {
            let want = r0[i] + scale * t[i];
            assert!((got[i] - want).abs() <= 1e-6 * want.abs().max(1.0), "i={i}");
        }
    }
}

#[test]
fn mlp_loss_grad_runs_and_is_finite() {
    require_artifacts!();
    let engine = Engine::load(default_artifact_dir()).unwrap();
    let meta = engine.manifest.mlp;
    let mut rng = SplitMix64::new(23);
    let params: Vec<f32> = rng.normal_vec(meta.params).iter().map(|x| x * 0.05).collect();
    let x = rng.normal_vec(meta.batch * meta.d_in);
    let y = rng.normal_vec(meta.batch * meta.d_out);
    let (loss, grad) = engine.mlp_loss_grad(&params, &x, &y).unwrap();
    assert!(loss.is_finite() && loss >= 0.0);
    assert_eq!(grad.len(), meta.params);
    assert!(grad.iter().all(|g| g.is_finite()));
    // gradient direction check: a small step against the gradient reduces
    // the loss on the same batch
    let step = 0.01;
    let params2: Vec<f32> =
        params.iter().zip(&grad).map(|(w, g)| w - step * g).collect();
    let (loss2, _) = engine.mlp_loss_grad(&params2, &x, &y).unwrap();
    assert!(loss2 < loss, "descent failed: {loss} → {loss2}");
}

#[test]
fn service_op_allreduce_through_threads_matches_native() {
    require_artifacts!();
    let svc = ComputeService::start(default_artifact_dir(), vec!["sum".into()], false, false)
        .unwrap();
    let p = 4;
    let m = 2048;
    let handle = svc.handle.clone();
    let out_pjrt = Launcher::new(p).backend(OpBackend::Pjrt(handle)).run(move |mut comm| {
        let mut v: Vec<f32> = (0..m).map(|j| ((comm.rank() + 1) * (j % 13)) as f32).collect();
        comm.allreduce(&mut v, "sum").unwrap();
        v
    });
    let out_native = Launcher::new(p).backend(OpBackend::Native).run(move |mut comm| {
        let mut v: Vec<f32> = (0..m).map(|j| ((comm.rank() + 1) * (j % 13)) as f32).collect();
        comm.allreduce(&mut v, "sum").unwrap();
        v
    });
    assert_eq!(out_pjrt, out_native, "PJRT and native backends must agree exactly");
}

#[test]
fn engine_stats_track_padding_and_chunking() {
    require_artifacts!();
    let engine = Engine::load(default_artifact_dir()).unwrap();
    let n = 1500; // needs padding on any bucket set
    let mut a = vec![1.0f32; n];
    let b = vec![2.0f32; n];
    engine.combine_into("sum", &mut a, &b, 0.0).unwrap();
    let stats = engine.stats.lock().unwrap().clone();
    assert!(stats.executions >= 1);
    assert!(stats.compiles >= 1);
    // 1500 is not a bucket; padding must have happened
    assert!(stats.padded_elems > 0, "{stats:?}");
}

#[test]
fn training_smoke_converges() {
    require_artifacts!();
    use circulant_collectives::coordinator::{train, TrainConfig};
    let cfg = TrainConfig {
        workers: 2,
        steps: 25,
        lr: 0.05,
        seed: 11,
        log_every: 0,
        pjrt_reduce: true,
        scheme: circulant_collectives::topology::skips::SkipScheme::HalvingUp,
    };
    let report = train(&default_artifact_dir(), &cfg).unwrap();
    assert_eq!(report.workers, 2);
    // losses is empty when log_every=0 except... keep a loose check:
    assert!(report.wall_seconds > 0.0);
}
