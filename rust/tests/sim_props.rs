//! Simulator properties: DES agreement with the paper's closed forms and
//! the structural inequalities between algorithms.

use circulant_collectives::collectives::Algorithm;
use circulant_collectives::datatypes::BlockPartition;
use circulant_collectives::sim::{closed_form, simulate, CostModel};
use circulant_collectives::util::rng::SplitMix64;

#[test]
fn des_equals_corollary1_exactly_on_regular_partitions() {
    // The asynchronous DES must telescope to Corollary 1's closed form for
    // Algorithm 1 on uniform blocks (m divisible by p for exactness).
    let model = CostModel::new(2.0, 3e-4, 7e-5);
    let mut rng = SplitMix64::new(5);
    for _ in 0..80 {
        let p = 2 + rng.next_below(300);
        let b = 1 + rng.next_below(500);
        let m = p * b;
        let part = BlockPartition::uniform(p, b);
        let sched = Algorithm::parse("rs").unwrap().schedule(p);
        let sim = simulate(&sched, &part, &model);
        let cf = closed_form::alg1_reduce_scatter(&model, p, m);
        assert!(
            (sim.total - cf).abs() <= 1e-9 * cf,
            "p={p} b={b}: DES {} vs Corollary 1 {}",
            sim.total,
            cf
        );
    }
}

#[test]
fn des_equals_theorem2_form_for_allreduce() {
    let model = CostModel::new(1.0, 1e-4, 5e-5);
    let mut rng = SplitMix64::new(6);
    for _ in 0..60 {
        let p = 2 + rng.next_below(200);
        let b = 1 + rng.next_below(200);
        let part = BlockPartition::uniform(p, b);
        let sched = Algorithm::parse("ar").unwrap().schedule(p);
        let sim = simulate(&sched, &part, &model);
        let cf = closed_form::alg2_allreduce(&model, p, p * b);
        assert!((sim.total - cf).abs() <= 1e-9 * cf, "p={p} b={b}");
    }
}

#[test]
fn corollary3_bound_holds_for_random_irregular_partitions() {
    let model = CostModel::cluster();
    let mut rng = SplitMix64::new(8);
    for _ in 0..100 {
        let p = 2 + rng.next_below(100);
        let m = 1 + rng.next_below(100_000);
        let part = BlockPartition::random(p, m, rng.next_u64());
        let sched = Algorithm::parse("rs").unwrap().schedule(p);
        let sim = simulate(&sched, &part, &model);
        let bound = closed_form::corollary3_bound(&model, p, m);
        assert!(sim.total <= bound * (1.0 + 1e-9), "p={p} m={m}: {} > {}", sim.total, bound);
    }
}

#[test]
fn ring_des_matches_ring_closed_form() {
    let model = CostModel::new(1.0, 1e-4, 3e-5);
    for p in [2usize, 5, 16, 33, 100] {
        let b = 13;
        let part = BlockPartition::uniform(p, b);
        let sim = simulate(&Algorithm::RingAllreduce.schedule(p), &part, &model);
        let cf = closed_form::ring_allreduce(&model, p, p * b);
        assert!((sim.total - cf).abs() <= 1e-9 * cf.max(1.0), "p={p}: {} vs {}", sim.total, cf);
    }
}

#[test]
fn volume_dominance_alg2_vs_ring_everywhere() {
    // Identical volume, strictly fewer rounds ⇒ Alg 2 ≤ ring in the model,
    // for every p and m.
    let model = CostModel::cluster();
    let mut rng = SplitMix64::new(9);
    for _ in 0..100 {
        let p = 2 + rng.next_below(500);
        let m = 1 + rng.next_below(1 << 22);
        let a = closed_form::alg2_allreduce(&model, p, m);
        let r = closed_form::ring_allreduce(&model, p, m);
        assert!(a <= r + 1e-12, "p={p} m={m}: alg2 {a} > ring {r}");
    }
}

#[test]
fn des_monotone_in_alpha_beta_gamma() {
    let part = BlockPartition::regular(37, 3700);
    let sched = Algorithm::parse("ar").unwrap().schedule(37);
    let base = simulate(&sched, &part, &CostModel::new(1.0, 1e-3, 1e-4)).total;
    for scaled in [
        CostModel::new(2.0, 1e-3, 1e-4),
        CostModel::new(1.0, 2e-3, 1e-4),
        CostModel::new(1.0, 1e-3, 2e-4),
    ] {
        assert!(simulate(&sched, &part, &scaled).total > base);
    }
}

#[test]
fn idle_and_degenerate_cases() {
    let model = CostModel::cluster();
    // p = 1: nothing to do
    let part = BlockPartition::regular(1, 100);
    let sched = Algorithm::parse("ar").unwrap().schedule(1);
    assert_eq!(simulate(&sched, &part, &model).total, 0.0);
    // m = 0: pure α cost (rounds still happen with empty payloads)
    let p = 8;
    let part = BlockPartition::regular(p, 0);
    let sched = Algorithm::parse("ar").unwrap().schedule(p);
    let t = simulate(&sched, &part, &model).total;
    assert!((t - 6.0 * model.alpha).abs() < 1e-15, "t={t}");
}

#[test]
fn selector_agrees_with_des_ranking() {
    // The closed-form selector must pick an algorithm whose DES time is
    // within 1% of the DES-best (sanity that formulas track the simulator).
    let model = CostModel::cluster();
    let mut rng = SplitMix64::new(31);
    for _ in 0..20 {
        let p = 2 + rng.next_below(120);
        let m = 1 << (4 + rng.next_below(16));
        let part = BlockPartition::regular(p, m);
        let mut best = f64::INFINITY;
        for alg in Algorithm::allreduce_family() {
            best = best.min(simulate(&alg.schedule(p), &part, &model).total);
        }
        let (chosen, _) = circulant_collectives::coordinator::select_allreduce(&model, p, m);
        let chosen_t = simulate(&chosen.schedule(p), &part, &model).total;
        assert!(chosen_t <= best * 1.01, "p={p} m={m}: {} at {chosen_t} vs best {best}", chosen.name());
    }
}
