//! Rendezvous-tier tests (ISSUE 2): the zero-copy precondition as a
//! property over every schedule generator, and bit-identity between the
//! rendezvous and pooled executors.
//!
//! The precondition (transport docs, `Schedule::rendezvous_safe`): in
//! every round, each rank's send and recv block ranges are disjoint, so a
//! receiver may read the sender's working vector while the sender writes
//! only its own recv range. Every generator in the library satisfies it
//! except full-vector recursive-doubling allreduce, whose butterfly
//! rounds exchange the *entire* vector both ways — the executor runs
//! those rounds on the pooled tier automatically, which the fallback
//! tests below pin down.

use std::sync::Arc;

use circulant_collectives::collectives::baselines;
use circulant_collectives::collectives::{
    allgather_schedule, allreduce_schedule, reduce_scatter_schedule, run_schedule_threads_tiered,
    Algorithm,
};
use circulant_collectives::datatypes::BlockPartition;
use circulant_collectives::ops::{Kernel, ReduceOp, SumOp};
use circulant_collectives::schedule::Schedule;
use circulant_collectives::topology::skips::SkipScheme;
use circulant_collectives::transport::{rendezvous_env_enabled, Counters};
use circulant_collectives::util::rng::SplitMix64;

/// Independent oracle for `Schedule::rendezvous_safe`: materialize each
/// step's send/recv block id sets and intersect them.
fn assert_send_recv_disjoint(sched: &Schedule) {
    let p = sched.p;
    for (k, round) in sched.rounds.iter().enumerate() {
        for (r, step) in round.steps.iter().enumerate() {
            if let (Some(send), Some(recv)) = (&step.send, &step.recv) {
                let blocks = |b: circulant_collectives::schedule::BlockRange| {
                    let b = b.normalized(p);
                    (0..b.len).map(|i| (b.start + i) % p).collect::<std::collections::HashSet<_>>()
                };
                let overlap: Vec<usize> =
                    blocks(send.blocks).intersection(&blocks(recv.blocks)).copied().collect();
                assert!(
                    overlap.is_empty(),
                    "{}: rank {r} round {k} send/recv share blocks {overlap:?}",
                    sched.name
                );
            }
        }
    }
    assert!(sched.rendezvous_safe(), "{}: rendezvous_safe disagrees with oracle", sched.name);
}

/// Random *valid* skip sequence (as in prop_schedules.rs): start at p,
/// next skip uniform in [⌈s/2⌉, s−1].
fn random_valid_skips(p: usize, rng: &mut SplitMix64) -> Vec<usize> {
    let mut v = Vec::new();
    let mut s = p;
    while s > 1 {
        let lo = s.div_ceil(2);
        let hi = s - 1;
        v.push(lo + rng.next_below(hi - lo + 1));
        s = *v.last().unwrap();
    }
    v
}

#[test]
fn circulant_schedules_satisfy_rendezvous_precondition_random_skips() {
    // Corollary-2 generality: ANY valid skip sequence keeps send/recv
    // ranges disjoint (the sent partials live at distance ≥ σ_k, the
    // received ones at the rank's own window — never the same blocks).
    let mut rng = SplitMix64::new(0xD15C0);
    for _ in 0..60 {
        let p = 2 + rng.next_below(96);
        let skips = random_valid_skips(p, &mut rng);
        assert_send_recv_disjoint(&reduce_scatter_schedule(p, &skips));
        assert_send_recv_disjoint(&allgather_schedule(p, &skips));
        assert_send_recv_disjoint(&allreduce_schedule(p, &skips));
    }
}

#[test]
fn baseline_generators_satisfy_rendezvous_precondition() {
    let mut rng = SplitMix64::new(0xBA5E);
    for &p in &[2usize, 3, 4, 5, 7, 8, 12, 16, 22, 31, 32] {
        let root = rng.next_below(p);
        assert_send_recv_disjoint(&baselines::ring_reduce_scatter_schedule(p));
        assert_send_recv_disjoint(&baselines::ring_allgather_schedule(p));
        assert_send_recv_disjoint(&baselines::ring_allreduce_schedule(p));
        assert_send_recv_disjoint(&baselines::bruck_allgather_schedule(p));
        assert_send_recv_disjoint(&baselines::binomial_reduce_schedule(p, root));
        assert_send_recv_disjoint(&baselines::binomial_bcast_schedule(p, root));
        assert_send_recv_disjoint(&baselines::binomial_allreduce_schedule(p));
        assert_send_recv_disjoint(&baselines::binomial_scatter_schedule(p, root));
        assert_send_recv_disjoint(&baselines::binomial_gather_schedule(p, root));
        assert_send_recv_disjoint(&baselines::rabenseifner_allreduce_schedule(p));
        if p.is_power_of_two() {
            assert_send_recv_disjoint(&baselines::recursive_halving_rs_schedule(p));
            assert_send_recv_disjoint(&baselines::recursive_doubling_ag_schedule(p));
        }
    }
}

#[test]
fn recursive_doubling_allreduce_is_the_documented_exception() {
    // Full-vector butterfly rounds send and receive the SAME block range:
    // the precondition fails, and the executor must fall back per round.
    for p in [2usize, 3, 5, 8, 22] {
        let sched = baselines::recursive_doubling_allreduce_schedule(p);
        assert!(
            !sched.rendezvous_safe(),
            "p={p}: full-vector recursive doubling should not be rendezvous-safe"
        );
    }
}

fn int_inputs(p: usize, m: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = SplitMix64::new(seed);
    (0..p).map(|_| rng.int_valued_vec(m, -8, 9)).collect()
}

fn oracle_sum(inputs: &[Vec<f32>]) -> Vec<f32> {
    let mut acc = vec![0.0f32; inputs[0].len()];
    for v in inputs {
        for (a, b) in acc.iter_mut().zip(v) {
            *a += b;
        }
    }
    acc
}

#[test]
fn rendezvous_and_pooled_bit_identical_all_partitions() {
    // ISSUE-2 oracle: both tiers produce bit-identical buffers for
    // p ∈ {2, 5, 22} on random / zipf / degenerate single-block
    // partitions, and match the scalar oracle.
    for p in [2usize, 5, 22] {
        let parts = vec![
            ("random", BlockPartition::random(p, 7 * p + 3, 60 + p as u64)),
            ("zipf", BlockPartition::zipf(p, 9 * p, 1.3, p as u64)),
            ("single-block-0", BlockPartition::single_block(p, 41, 0)),
            ("single-block-last", BlockPartition::single_block(p, 33, p - 1)),
        ];
        for (wname, part) in parts {
            let inputs = int_inputs(p, part.total(), 17 + p as u64);
            let want = oracle_sum(&inputs);
            for alg_name in ["rs", "ar"] {
                let sched = Algorithm::parse(alg_name).unwrap().schedule(p);
                let rdv = run_schedule_threads_tiered(
                    &sched,
                    &part,
                    Arc::new(SumOp),
                    inputs.clone(),
                    true,
                );
                let pooled = run_schedule_threads_tiered(
                    &sched,
                    &part,
                    Arc::new(SumOp),
                    inputs.clone(),
                    false,
                );
                for r in 0..p {
                    // Bit-identical across tiers (same ⊕ order, different
                    // operand sourcing), not merely approximately equal.
                    let (rb, pb) = (&rdv[r].0, &pooled[r].0);
                    assert_eq!(rb.len(), pb.len());
                    for i in 0..rb.len() {
                        assert_eq!(
                            rb[i].to_bits(),
                            pb[i].to_bits(),
                            "{wname} {alg_name} p={p} r={r} i={i}"
                        );
                    }
                    // and correct vs the scalar oracle on the owned range
                    let range = if alg_name == "ar" {
                        0..part.total()
                    } else {
                        part.range(r)
                    };
                    assert_eq!(
                        &rdv[r].0[range.clone()],
                        &want[range],
                        "{wname} {alg_name} p={p} r={r}"
                    );
                }
                // the pooled run must never publish
                assert!(pooled.iter().all(|(_, c)| c.rendezvous_hits == 0), "{wname} {alg_name}");
            }
        }
    }
}

#[test]
fn rendezvous_engages_and_halves_copy_volume() {
    // On a rendezvous-safe allreduce every send publishes, and the copied
    // byte volume drops to the allgather-phase Store scatters alone —
    // strictly less than half the pooled volume (the bench asserts the
    // same ≥2× bound on large m; this is the test-sized mirror).
    let p = 5usize;
    let part = BlockPartition::regular(p, 10 * p);
    let sched = Algorithm::parse("ar").unwrap().schedule(p);
    let inputs = int_inputs(p, part.total(), 3);
    if !rendezvous_env_enabled() {
        // Under the CCOLL_NO_RENDEZVOUS kill-switch both runs are pooled;
        // engagement/copy-volume claims don't apply (bit-identity is
        // covered by the oracle test above).
        return;
    }
    let rdv = run_schedule_threads_tiered(&sched, &part, Arc::new(SumOp), inputs.clone(), true);
    let pooled = run_schedule_threads_tiered(&sched, &part, Arc::new(SumOp), inputs, false);
    fn total(out: &[(Vec<f32>, Counters)], f: fn(&Counters) -> u64) -> u64 {
        out.iter().map(|(_, c)| f(c)).sum()
    }
    let rdv_hits = total(&rdv, |c| c.rendezvous_hits);
    let rdv_msgs = total(&rdv, |c| c.msgs_sent);
    assert_eq!(rdv_hits, rdv_msgs, "every send of a safe schedule must publish");
    let rdv_bytes = total(&rdv, |c| c.bytes_copied);
    let pooled_bytes = total(&pooled, |c| c.bytes_copied);
    assert!(
        2 * rdv_bytes <= pooled_bytes,
        "rendezvous copied {rdv_bytes} bytes, pooled {pooled_bytes} — expected ≥2× reduction"
    );
    assert_eq!(total(&rdv, |c| c.pool_hits) + total(&rdv, |c| c.pool_misses), 0);
}

#[test]
fn recursive_doubling_fallback_is_correct_and_partial() {
    // With rendezvous requested on an unsafe schedule, the executor
    // degrades per round: butterfly rounds travel pooled, one-sided fold
    // rounds may still publish — and the result stays exact.
    for p in [2usize, 5, 22] {
        let part = BlockPartition::regular(p, 3 * p + 1);
        let sched = baselines::recursive_doubling_allreduce_schedule(p);
        let inputs = int_inputs(p, part.total(), 29 + p as u64);
        let want = oracle_sum(&inputs);
        let out = run_schedule_threads_tiered(&sched, &part, Arc::new(SumOp), inputs, true);
        for (r, (buf, _)) in out.iter().enumerate() {
            assert_eq!(buf, &want, "p={p} r={r}");
        }
        // Butterfly rounds must have used the pool on every rank that
        // participated in one (all ranks < 2^⌊log2 p⌋).
        let pool_acquires: u64 = out.iter().map(|(_, c)| c.pool_hits + c.pool_misses).sum();
        assert!(pool_acquires > 0, "p={p}: overlapping rounds should have gathered via the pool");
        if !p.is_power_of_two() && rendezvous_env_enabled() {
            // fold-in/out rounds are one-sided → rendezvous-eligible
            let hits: u64 = out.iter().map(|(_, c)| c.rendezvous_hits).sum();
            assert!(hits > 0, "p={p}: one-sided fold rounds should have published");
        }
    }
}

#[test]
fn kernel_dispatch_matches_dyn_dispatch_end_to_end() {
    // The executor takes the monomorphized-kernel path for native ops and
    // the dyn path for wrappers (kernel() == None). Both must produce
    // bit-identical collectives.
    struct DynOnly(SumOp);
    impl ReduceOp for DynOnly {
        fn name(&self) -> &'static str {
            "sum"
        }
        fn combine(&self, acc: &mut [f32], other: &[f32]) {
            self.0.combine(acc, other);
        }
        // kernel() deliberately left at the default None
        fn identity(&self) -> f32 {
            self.0.identity()
        }
    }
    assert!(SumOp.kernel().is_some());
    assert_eq!(SumOp.kernel(), Some(Kernel::Sum));

    for p in [2usize, 7, 22] {
        let part = BlockPartition::regular(p, 6 * p + 5);
        let sched = Algorithm::parse("ar").unwrap().schedule(p);
        let inputs = int_inputs(p, part.total(), 91 + p as u64);
        let fast =
            run_schedule_threads_tiered(&sched, &part, Arc::new(SumOp), inputs.clone(), true);
        let dynp =
            run_schedule_threads_tiered(&sched, &part, Arc::new(DynOnly(SumOp)), inputs, true);
        for r in 0..p {
            for i in 0..part.total() {
                assert_eq!(
                    fast[r].0[i].to_bits(),
                    dynp[r].0[i].to_bits(),
                    "p={p} r={r} i={i}"
                );
            }
        }
    }
}

#[test]
fn back_to_back_rendezvous_collectives_share_one_network() {
    // Round-tag offsets must keep publishes/acks of consecutive
    // collectives separated on a persistent network.
    use circulant_collectives::collectives::execute_rank;
    use circulant_collectives::transport::run_ranks_inputs;
    let p = 4usize;
    let m = 24usize;
    let part = Arc::new(BlockPartition::regular(p, m));
    let skips = SkipScheme::HalvingUp.skips(p).unwrap();
    let sched = Arc::new(allreduce_schedule(p, &skips));
    let iters = 12u64;
    let inputs: Vec<Vec<f32>> = (0..p).map(|r| vec![if r == 0 { 1.0 } else { 0.0 }; m]).collect();
    let out = run_ranks_inputs(inputs, move |_rank, ep, mut buf: Vec<f32>| {
        ep.rendezvous = true;
        ep.rendezvous_min_elems = 0;
        let mut tag = 0u64;
        for _ in 0..iters {
            tag = execute_rank(ep, &sched, &part, &SumOp, &mut buf, tag).unwrap();
        }
        (buf, ep.counters.clone())
    });
    // all ranks must agree exactly after every chained collective, and
    // the replicated vector stays constant across positions
    for (buf, c) in &out {
        assert_eq!(buf, &out[0].0, "ranks disagree after {iters} chained collectives");
        if rendezvous_env_enabled() {
            assert_eq!(c.rendezvous_hits, c.msgs_sent);
        }
    }
    assert!(out[0].0.iter().all(|&x| x == out[0].0[0]));
}
