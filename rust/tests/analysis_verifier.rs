//! Acceptance sweep for the static schedule verifier (`analysis`):
//!
//!   * every shipped algorithm audits clean for p ∈ 1..=64 under four
//!     partition shapes (regular, random, zipf, single-block) — the
//!     structure, exactly-once dataflow, paper-optimality and aliasing
//!     passes all hold (Theorems 1 and 2 as *checked* facts, not tests
//!     of specific p values);
//!   * the circulant generators are fully zero-copy (rendezvous)
//!     eligible at every step, as §3's in-place condition guarantees;
//!   * the mutation harness catches 100% of every injected corruption
//!     class with one of its named diagnostic codes — the verifier
//!     bites, it does not just bless;
//!   * defect classes the mutation harness cannot reach (count-envelope
//!     violations with clean dataflow) are still caught and named.

use circulant_collectives::analysis::{
    self,
    mutate::{self, Mutation},
};
use circulant_collectives::collectives::{
    try_allgather_schedule, try_allreduce_schedule, try_reduce_scatter_schedule, Algorithm,
};
use circulant_collectives::datatypes::BlockPartition;
use circulant_collectives::schedule::BlockRange;
use circulant_collectives::topology::skips::SkipScheme;

#[test]
fn every_shipped_algorithm_audits_clean_up_to_p64() {
    for p in 1..=64usize {
        let m = 3 * p + 1; // deliberately not divisible by p
        let parts = [
            BlockPartition::regular(p, m),
            BlockPartition::random(p, m, 0xA5 ^ p as u64),
            BlockPartition::zipf(p, m, 1.2, 7 + p as u64),
            BlockPartition::single_block(p, m, p / 2),
        ];
        let refs: Vec<&BlockPartition> = parts.iter().collect();
        for alg in analysis::shipped_roster(p) {
            let rep = analysis::audit_algorithm(&alg, p, &refs)
                .unwrap_or_else(|e| panic!("{} p={p}: [{}] {e}", alg.name(), e.code()));
            assert_eq!(rep.partitions_checked, 4, "{} p={p}", alg.name());
            // §3: the in-place condition makes every circulant round's
            // send/recv ranges disjoint — all steps zero-copy eligible.
            if matches!(
                alg,
                Algorithm::CirculantReduceScatter(_)
                    | Algorithm::CirculantAllreduce(_)
                    | Algorithm::CirculantAllgather(_)
            ) {
                assert_eq!(
                    rep.tier_counts.0,
                    rep.tier_counts.1,
                    "{} p={p}: not fully rendezvous-eligible",
                    alg.name()
                );
            }
        }
    }
}

#[test]
fn mutation_harness_catches_every_class_with_named_codes() {
    for p in [16usize, 22] {
        let part = BlockPartition::regular(p, 2 * p);
        for alg in [
            Algorithm::CirculantReduceScatter(SkipScheme::HalvingUp),
            Algorithm::CirculantAllreduce(SkipScheme::HalvingUp),
        ] {
            let (sem, env) = analysis::expectation(&alg, p);
            for m in Mutation::ALL {
                let mut applied = 0usize;
                for seed in 0..16u64 {
                    let mut sched = alg.schedule(p);
                    if !mutate::apply(&mut sched, m, seed) {
                        continue;
                    }
                    applied += 1;
                    let err = analysis::audit_schedule(&sched, sem, &env, &[&part])
                        .expect_err(&format!(
                            "{} p={p}: mutation {} seed {seed} NOT caught",
                            alg.name(),
                            m.name()
                        ));
                    assert!(
                        m.expected_codes().contains(&err.code()),
                        "{} p={p}: mutation {} seed {seed} caught as [{}], expected one of {:?}",
                        alg.name(),
                        p,
                        m.name(),
                        err.code(),
                        m.expected_codes()
                    );
                }
                // Only DuplicateContribution can be inapplicable (a pure
                // reduce-scatter has no Store recv to flip).
                if m != Mutation::DuplicateContribution
                    || alg == Algorithm::CirculantAllreduce(SkipScheme::HalvingUp)
                {
                    assert!(applied > 0, "{} p={p}: {} never applied", alg.name(), m.name());
                }
            }
        }
    }
}

/// A count-envelope violation with *clean* dataflow: widen one transfer
/// (both sides, so the round still matches) into a block whose cell the
/// reduce-scatter semantics never checks. The only pass that can catch
/// it is the Theorem 1 block-count envelope — and it must.
#[test]
fn redundant_transfer_is_caught_by_the_block_count_envelope() {
    let p = 8usize;
    let alg = Algorithm::CirculantReduceScatter(SkipScheme::HalvingUp);
    let (sem, env) = analysis::expectation(&alg, p);
    let mut sched = alg.schedule(p);
    // First transfer of round 0: widen send + matching recv by one block.
    let (r, send) = sched.rounds[0]
        .steps
        .iter()
        .enumerate()
        .find_map(|(r, s)| s.send.map(|t| (r, t)))
        .expect("round 0 has a transfer");
    let wide = BlockRange::new(send.blocks.start, send.blocks.len + 1);
    sched.rounds[0].steps[r].send.as_mut().unwrap().blocks = wide;
    sched.rounds[0].steps[send.peer].recv.as_mut().unwrap().blocks = wide;
    let part = BlockPartition::regular(p, 2 * p);
    let err = analysis::audit_schedule(&sched, sem, &env, &[&part]).unwrap_err();
    assert_eq!(err.code(), "block-count", "{err}");
}

#[test]
fn try_generators_surface_typed_skip_errors() {
    // [3, 1] violates the in-place condition for p=8 (needs σ₁ ≥ ⌈8/2⌉).
    for res in [
        try_reduce_scatter_schedule(8, &[3, 1]).map(|_| ()),
        try_allreduce_schedule(8, &[3, 1]).map(|_| ()),
        try_allgather_schedule(8, &[3, 1]).map(|_| ()),
    ] {
        let err = res.expect_err("invalid skip sequence must be rejected");
        assert_eq!(err.code(), "bad-skips");
    }
    // The valid sequence still builds and audits clean end to end.
    let sched = try_allreduce_schedule(8, &[4, 2, 1]).unwrap();
    analysis::verify_allreduce(&sched).unwrap();
}
