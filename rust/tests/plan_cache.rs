//! Plan-cache suite (ISSUE-4): repeated identical operations hit the
//! cache (counter-asserted), differing partition/dtype/scheme miss, and
//! cached-schedule results stay bit-identical to freshly generated ones.

use std::sync::Arc;

use circulant_collectives::collectives::{allreduce_schedule, run_schedule_threads_typed};
use circulant_collectives::coordinator::Launcher;
use circulant_collectives::datatypes::{elem, BlockPartition, DType, Elem};
use circulant_collectives::engine::{CollectiveEngine, EngineConfig, OpRequest};
use circulant_collectives::ops::{ReduceOp, SumOp};
use circulant_collectives::schedule::{PlanCache, PlanKey};
use circulant_collectives::topology::skips::SkipScheme;
use circulant_collectives::util::rng::SplitMix64;

fn int_inputs<T: Elem>(p: usize, m: usize, seed: u64) -> Vec<Vec<T>> {
    let (lo, hi) = elem::test_value_bounds(T::DTYPE);
    let mut rng = SplitMix64::new(seed);
    (0..p).map(|_| elem::int_vec(&mut rng, m, lo, hi)).collect()
}

#[test]
fn second_identical_engine_op_is_a_cache_hit() {
    let p = 6;
    let mut engine = CollectiveEngine::<i64>::new(EngineConfig::new(p));
    engine.submit(OpRequest::allreduce(int_inputs(p, 40, 1), "sum")).unwrap().wait().unwrap();
    let s1 = engine.plan_stats();
    assert_eq!((s1.hits, s1.misses, s1.entries), (0, 1, 1), "first op builds");
    engine.submit(OpRequest::allreduce(int_inputs(p, 40, 2), "sum")).unwrap().wait().unwrap();
    let s2 = engine.plan_stats();
    assert_eq!((s2.hits, s2.misses, s2.entries), (1, 1, 1), "second identical op hits");
    // Different size → different partition → miss; different kind → miss.
    engine.submit(OpRequest::allreduce(int_inputs(p, 41, 3), "sum")).unwrap().wait().unwrap();
    engine.submit(OpRequest::reduce_scatter(int_inputs(p, 40, 4), "sum")).unwrap().wait().unwrap();
    let s3 = engine.plan_stats();
    assert_eq!((s3.hits, s3.misses, s3.entries), (1, 3, 3));
    // A different ⊕ on the same geometry still hits: plans don't depend
    // on the operator.
    engine.submit(OpRequest::allreduce(int_inputs(p, 40, 5), "max")).unwrap().wait().unwrap();
    assert_eq!(engine.plan_stats().hits, 2);
    engine.shutdown();
}

#[test]
fn differing_partition_dtype_scheme_are_misses_unit() {
    // Key-level coverage (no engine): the four key components each
    // discriminate.
    let cache = PlanCache::new();
    let p = 5;
    let part_a = BlockPartition::regular(p, 50);
    let part_b = BlockPartition::regular(p, 55);
    let skips = SkipScheme::HalvingUp.skips(p).unwrap();
    let build = || allreduce_schedule(p, &skips);
    let (_, hit) =
        cache.get_or_build(PlanKey::new("ar:halving-up", p, &part_a, DType::I64), &part_a, build);
    assert!(!hit);
    for (key, part) in [
        (PlanKey::new("ar:halving-up", p, &part_b, DType::I64), &part_b), // partition differs
        (PlanKey::new("ar:halving-up", p, &part_a, DType::U64), &part_a), // dtype differs
        (PlanKey::new("ar:pow2", p, &part_a, DType::I64), &part_a),       // scheme differs
        (PlanKey::new("rs:halving-up", p, &part_a, DType::I64), &part_a), // algorithm differs
    ] {
        let (_, hit) = cache.get_or_build(key, part, build);
        assert!(!hit, "distinct key must miss");
    }
    let (_, hit) =
        cache.get_or_build(PlanKey::new("ar:halving-up", p, &part_a, DType::I64), &part_a, build);
    assert!(hit, "original key still hits");
    let stats = cache.stats();
    assert_eq!((stats.hits, stats.misses, stats.entries), (1, 5, 5));
}

#[test]
fn cached_plans_are_bit_identical_to_fresh_schedules() {
    // Engine results on a warm cache vs the standalone threaded executor
    // with a freshly generated schedule: exact i64 equality.
    let p = 5;
    let m = 4 * p + 3;
    let part = BlockPartition::regular(p, m);
    let skips = SkipScheme::HalvingUp.skips(p).unwrap();
    let mut engine = CollectiveEngine::<i64>::new(EngineConfig::new(p));
    // Warm the cache, then run the measured op through the cached plan.
    engine.submit(OpRequest::allreduce(int_inputs(p, m, 50), "sum")).unwrap().wait().unwrap();
    let cached =
        engine.submit(OpRequest::allreduce(int_inputs(p, m, 51), "sum")).unwrap().wait().unwrap();
    assert!(engine.plan_stats().hits >= 1, "second op must come from the cache");
    engine.shutdown();
    let fresh_sched = allreduce_schedule(p, &skips); // regenerated from scratch
    let op: Arc<dyn ReduceOp<i64>> = Arc::new(SumOp);
    let fresh = run_schedule_threads_typed::<i64>(&fresh_sched, &part, op, int_inputs(p, m, 51));
    assert_eq!(cached, fresh, "cached plan diverged from freshly generated schedule");
}

#[test]
fn engine_and_communicator_share_one_plan_key_space() {
    // The engine and the communicator derive their plan keys through the
    // same CirculantPlans vocabulary; a communicator handed an engine's
    // cache must HIT the plan the engine already built — if the two
    // entry points' canonical names ever drifted apart, this would miss.
    use circulant_collectives::coordinator::{Communicator, OpBackend};
    let p = 4;
    let m = 20;
    let mut engine = CollectiveEngine::<f32>::new(EngineConfig::new(p));
    engine
        .submit(OpRequest::allreduce(vec![vec![1.0f32; m]; p], "sum"))
        .unwrap()
        .wait()
        .unwrap();
    let plans = engine.plan_cache();
    engine.shutdown();
    let misses_before = plans.stats().misses;
    let plans2 = plans.clone();
    let hits = circulant_collectives::transport::run_ranks(p, move |_rank, ep| {
        let owned =
            std::mem::replace(ep, circulant_collectives::transport::network(1).pop().unwrap());
        let mut comm = Communicator::new(owned, SkipScheme::HalvingUp, OpBackend::Native);
        comm.set_plan_cache(plans2.clone());
        let mut buf = vec![1.0f32; m];
        comm.allreduce(&mut buf, "sum").unwrap();
        comm.counters().plan_hits
    });
    assert!(hits.iter().all(|&h| h == 1), "communicator missed the engine-built plan: {hits:?}");
    assert_eq!(plans.stats().misses, misses_before, "no new plan may be built");
}

#[test]
fn communicator_counters_expose_plan_hits() {
    // The per-rank transport counters mirror cache outcomes, so
    // RunMetrics (which aggregates Counters) reports them.
    let p = 3;
    let out = Launcher::new(p).run(move |mut comm| {
        let mut a = vec![1.0f32; 30];
        comm.allreduce(&mut a, "sum").unwrap();
        comm.allreduce(&mut a, "sum").unwrap();
        comm.allreduce(&mut a, "sum").unwrap();
        comm.counters()
    });
    for (rank, c) in out.iter().enumerate() {
        assert_eq!(c.plan_hits + c.plan_misses, 3, "rank {rank}: three lookups");
        assert!(c.plan_hits >= 2, "rank {rank}: repeats must hit the shared cache");
    }
    // Aggregate across the job: only the first call can build. Ranks
    // race on that first lookup (builds run outside the cache lock), so
    // between 1 and p misses are legal; 9 lookups happened in total.
    let total_misses: u64 = out.iter().map(|c| c.plan_misses).sum();
    let total_hits: u64 = out.iter().map(|c| c.plan_hits).sum();
    assert!(
        (1..=p as u64).contains(&total_misses),
        "launcher shares one cache across ranks (misses={total_misses})"
    );
    assert_eq!(total_hits + total_misses, 3 * p as u64);
}

#[test]
fn every_communicator_collective_is_plan_cached() {
    // Each API (allreduce, reduce_scatter*, allgather, reduce, bcast,
    // scatter, gather) resolves through the cache: running the same
    // program twice on one communicator doubles lookups but builds no
    // new plans.
    let p = 4;
    let b = 3;
    let out = Launcher::new(p).run(move |mut comm| {
        let mut lookups = Vec::new();
        for _ in 0..2 {
            let mut buf = vec![1.0f32; p * b];
            comm.allreduce(&mut buf, "sum").unwrap();
            let send: Vec<f32> = vec![1.0; p * b];
            let mut recv = vec![0.0f32; b];
            comm.reduce_scatter_block(&send, &mut recv, "sum").unwrap();
            let mine = vec![comm.rank() as f32; b];
            let mut all = vec![0.0f32; p * b];
            comm.allgather(&mine, &mut all).unwrap();
            let mut r = vec![1.0f32; 7];
            comm.reduce(&mut r, 0, "sum").unwrap();
            comm.bcast(&mut r, 0).unwrap();
            let sendbuf: Option<Vec<f32>> =
                (comm.rank() == 0).then(|| vec![1.0f32; p * b]);
            let mut mine2 = vec![0.0f32; b];
            comm.scatter(sendbuf.as_deref(), &mut mine2, 0).unwrap();
            let mut gath = (comm.rank() == 0).then(|| vec![0.0f32; p * b]);
            comm.gather(&mine2, gath.as_deref_mut(), 0).unwrap();
            let c = comm.counters();
            lookups.push((c.plan_hits, c.plan_misses));
        }
        lookups
    });
    let pass1_misses: u64 = out.iter().map(|l| l[0].1).sum();
    let pass2_misses: u64 = out.iter().map(|l| l[1].1).sum();
    assert_eq!(
        pass2_misses, pass1_misses,
        "second pass of the same program must build zero new plans"
    );
    for (rank, l) in out.iter().enumerate() {
        let (h1, _) = l[0];
        let (h2, _) = l[1];
        assert!(h2 > h1, "rank {rank}: second pass produced no cache hits");
    }
}
