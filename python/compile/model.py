"""Layer-2 JAX compute graphs, AOT-lowered for the Rust coordinator.

Two families of graphs are defined here, both lowered to HLO text by
:mod:`compile.aot` and executed from Rust through PJRT
(``rust/src/runtime/``):

1. ``bulk_combine`` / ``bulk_combine_scaled`` — the per-round block combine
   of the paper's Algorithm 1/2 (the γ term of Corollary 1), delegating to
   the Layer-1 Pallas kernel in :mod:`compile.kernels.combine`.  One
   executable is compiled per (operator, bucket-length) pair; the Rust
   runtime rounds requests up to the nearest bucket (shape bucketing, the
   standard serving-system answer to XLA's static shapes).

2. ``mlp_loss_and_grad`` — forward + backward of a small MLP regressor over
   a *flat* parameter vector.  This is the per-worker compute of the
   end-to-end data-parallel training driver
   (``examples/train_allreduce.rs``): each simulated worker evaluates
   loss+grad on its shard via PJRT, then the gradient vectors are averaged
   across workers with the paper's allreduce (Algorithm 2).  Keeping the
   parameters flat means the Rust side never needs to know the pytree
   structure — gradients are exactly the 1-D vectors the collective
   partitions into p blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import combine as pallas_combine
from .kernels.combine import combine_scaled as pallas_combine_scaled

# ---------------------------------------------------------------------------
# Bulk combine graphs (wrap the L1 kernel so each lowers to one artifact).
# ---------------------------------------------------------------------------


def bulk_combine(a, b, *, op: str):
    """``a ⊕ b`` over two equal 1-D buffers via the Pallas kernel."""
    return (pallas_combine(a, b, op=op),)


def bulk_combine_scaled(r, t, scale):
    """``r + scale·t`` (fused gradient-averaging combine)."""
    return (pallas_combine_scaled(r, t, scale),)


# ---------------------------------------------------------------------------
# MLP for the E2E training driver.
# ---------------------------------------------------------------------------

#: Architecture of the training-example model. Sizes are chosen so the flat
#: parameter vector (~74.5k f32) partitions into interesting block counts
#: for 2..16 simulated workers while staying fast under CPU interpret mode.
MLP_IN = 32
MLP_HIDDEN = 256
MLP_OUT = 1
MLP_BATCH = 64


def mlp_param_count(d_in: int = MLP_IN, h: int = MLP_HIDDEN, d_out: int = MLP_OUT) -> int:
    """Number of scalars in the flat parameter vector."""
    return d_in * h + h + h * h + h + h * d_out + d_out


def _unflatten(params, d_in: int, h: int, d_out: int):
    """Slice the flat vector into (W1, b1, W2, b2, W3, b3)."""
    o = 0

    def take(n, shape):
        nonlocal o
        v = params[o : o + n].reshape(shape)
        o += n
        return v

    w1 = take(d_in * h, (d_in, h))
    b1 = take(h, (h,))
    w2 = take(h * h, (h, h))
    b2 = take(h, (h,))
    w3 = take(h * d_out, (h, d_out))
    b3 = take(d_out, (d_out,))
    return w1, b1, w2, b2, w3, b3


def mlp_forward(params, x, *, d_in: int = MLP_IN, h: int = MLP_HIDDEN, d_out: int = MLP_OUT):
    """Two-hidden-layer tanh MLP over a flat parameter vector."""
    w1, b1, w2, b2, w3, b3 = _unflatten(params, d_in, h, d_out)
    z = jnp.tanh(x @ w1 + b1)
    z = jnp.tanh(z @ w2 + b2)
    return z @ w3 + b3


def mlp_loss(params, x, y, **kw):
    """Mean-squared-error regression loss."""
    pred = mlp_forward(params, x, **kw)
    return jnp.mean((pred - y) ** 2)


def mlp_loss_and_grad(params, x, y):
    """``(loss, grad)`` — the artifact the Rust training driver executes.

    Returns a 2-tuple ``(f32[], f32[P])``; the gradient is flat and is fed
    straight into the Algorithm 2 allreduce across workers.
    """
    loss, grad = jax.value_and_grad(mlp_loss)(params, x, y)
    return loss, grad


def mlp_init(seed: int = 0, *, d_in: int = MLP_IN, h: int = MLP_HIDDEN, d_out: int = MLP_OUT):
    """Glorot-ish initial flat parameter vector.

    Used by the python tests. The Rust training driver uses its own
    equally-scaled splitmix64 init (rust/src/coordinator/train.rs) — the
    two need not produce identical values, only identical *shapes*; every
    worker replica shares whichever init its driver generates.
    """
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    w1 = jax.random.normal(k1, (d_in, h)) * (1.0 / jnp.sqrt(d_in))
    w2 = jax.random.normal(k2, (h, h)) * (1.0 / jnp.sqrt(h))
    w3 = jax.random.normal(k3, (h, d_out)) * (1.0 / jnp.sqrt(h))
    parts = [
        w1.reshape(-1),
        jnp.zeros((h,)),
        w2.reshape(-1),
        jnp.zeros((h,)),
        w3.reshape(-1),
        jnp.zeros((d_out,)),
    ]
    return jnp.concatenate(parts).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Lowering helpers (used by compile.aot).
# ---------------------------------------------------------------------------


def lower_combine(op: str, n: int):
    """Jit-lower ``bulk_combine`` for 1-D f32 length ``n``."""
    spec = jax.ShapeDtypeStruct((n,), jnp.float32)
    fn = functools.partial(bulk_combine, op=op)
    return jax.jit(fn).lower(spec, spec)


def lower_combine_scaled(n: int):
    """Jit-lower ``bulk_combine_scaled`` for 1-D f32 length ``n``."""
    spec = jax.ShapeDtypeStruct((n,), jnp.float32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)
    return jax.jit(bulk_combine_scaled).lower(spec, spec, scalar)


def lower_mlp(batch: int = MLP_BATCH):
    """Jit-lower ``mlp_loss_and_grad`` for the default architecture."""
    p = mlp_param_count()
    params = jax.ShapeDtypeStruct((p,), jnp.float32)
    x = jax.ShapeDtypeStruct((batch, MLP_IN), jnp.float32)
    y = jax.ShapeDtypeStruct((batch, MLP_OUT), jnp.float32)
    return jax.jit(mlp_loss_and_grad).lower(params, x, y)
