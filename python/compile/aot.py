"""AOT bridge: lower every Layer-2 graph to HLO *text* + a manifest.

Run once at build time (``make artifacts``); Python is never on the Rust
request path.  Interchange format is HLO **text**, not a serialized
``HloModuleProto``: jax ≥ 0.5 emits protos with 64-bit instruction ids that
the image's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``), while
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Artifacts written to ``--out-dir`` (default ``artifacts/``):

  combine_<op>_<n>.hlo.txt         (a: f32[n], b: f32[n]) -> (f32[n],)
  combine_scaled_<n>.hlo.txt       (r: f32[n], t: f32[n], s: f32[]) -> (f32[n],)
  mlp_loss_grad.hlo.txt            (params: f32[P], x: f32[B,D], y: f32[B,1])
                                       -> (f32[], f32[P])
  manifest.json                    index of the above, parsed by
                                   rust/src/runtime/manifest.rs

Usage: ``python -m compile.aot [--out-dir DIR] [--quick]``.
``--quick`` restricts to the smallest bucket (used by pytest so the test
suite doesn't spend minutes lowering the big buckets).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax

from . import model
from .kernels.ref import OPS

#: Bucket lengths (f32 elements) for the combine executables.  The Rust
#: runtime rounds a requested combine length up to the nearest bucket and
#: pads; buckets are spaced 8× so padding waste is bounded and the compile
#: count stays small.  All are multiples of the kernel ALIGN (1024).
BUCKETS = (1024, 8192, 65536, 262144)


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR → XlaComputation → HLO text (the 0.5.1-safe path)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(out_dir: str, name: str, text: str) -> dict:
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        f.write(text)
    digest = hashlib.sha256(text.encode()).hexdigest()[:16]
    return {"file": name, "sha256_16": digest, "bytes": len(text)}


def build_manifest(out_dir: str, quick: bool = False) -> dict:
    """Lower everything and return the manifest dict."""
    os.makedirs(out_dir, exist_ok=True)
    buckets = BUCKETS[:1] if quick else BUCKETS
    entries = []

    for op in OPS:
        for n in buckets:
            lowered = model.lower_combine(op, n)
            meta = _write(out_dir, f"combine_{op}_{n}.hlo.txt", to_hlo_text(lowered))
            meta.update(kind="combine", op=op, n=n, inputs=[[n], [n]], outputs=[[n]])
            entries.append(meta)
            print(f"  lowered combine_{op}_{n}")

    for n in buckets:
        lowered = model.lower_combine_scaled(n)
        meta = _write(out_dir, f"combine_scaled_{n}.hlo.txt", to_hlo_text(lowered))
        meta.update(kind="combine_scaled", op="fma", n=n, inputs=[[n], [n], []], outputs=[[n]])
        entries.append(meta)
        print(f"  lowered combine_scaled_{n}")

    p = model.mlp_param_count()
    lowered = model.lower_mlp()
    meta = _write(out_dir, "mlp_loss_grad.hlo.txt", to_hlo_text(lowered))
    meta.update(
        kind="mlp_loss_grad",
        op="none",
        n=p,
        inputs=[[p], [model.MLP_BATCH, model.MLP_IN], [model.MLP_BATCH, model.MLP_OUT]],
        outputs=[[], [p]],
    )
    entries.append(meta)
    print(f"  lowered mlp_loss_grad (P={p})")

    return {
        "format": 1,
        "jax": jax.__version__,
        "buckets": list(buckets),
        "ops": list(OPS),
        "mlp": {
            "params": p,
            "d_in": model.MLP_IN,
            "hidden": model.MLP_HIDDEN,
            "d_out": model.MLP_OUT,
            "batch": model.MLP_BATCH,
        },
        "artifacts": entries,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact output directory")
    ap.add_argument("--quick", action="store_true", help="smallest bucket only (tests)")
    # Back-compat with the original scaffold Makefile which passed --out FILE.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir

    manifest = build_manifest(out_dir, quick=args.quick)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['artifacts'])} artifacts + manifest.json to {out_dir}/")


if __name__ == "__main__":
    main()
