"""Layer-1 Pallas kernels for the paper's compute hot-spot (block combine).

Modules:
  combine -- tiled elementwise binary combine (the γ term of Corollary 1)
  ref     -- pure-jnp oracles used by pytest/hypothesis
"""

from .combine import combine, combine_scaled, choose_tile, DEFAULT_TILE  # noqa: F401
from .ref import OPS, combine_ref, reduce_blocks_ref  # noqa: F401
