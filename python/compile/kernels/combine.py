"""Layer-1 Pallas kernels: tiled elementwise block combine.

The compute hot-spot of the paper's Algorithm 1/2 is the γ term of
Corollary 1 — per communication round, each processor applies the
commutative operator ⊕ to a *consecutive* run of received partial-result
blocks: ``R[0 … s'−s−1] ← R[0 … s'−s−1] ⊕ T[0 … s'−s−1]``.  Because the
paper keeps all block sequences contiguous (§3), this is a single 1-D
elementwise combine over ``n`` elements, which we express as a Pallas
kernel tiled for VMEM.

TPU adaptation (DESIGN.md §Hardware-Adaptation): there is no matmul here,
so the MXU is irrelevant — the kernel is VPU/bandwidth bound.  The
``BlockSpec`` grid streams ``TILE``-element chunks HBM→VMEM; with three
live f32 buffers per tile (a, b, out) the VMEM footprint is
``3 · TILE · 4 B = 96 KiB`` for the default ``TILE = 8192``, comfortably
inside a TensorCore's ~16 MiB VMEM and aligned to the 8×128 lane layout
(8192 = 64 · 128).

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; the interpret path lowers to plain HLO so the same artifact
runs under the Rust PJRT client.  Numerics are validated against
:mod:`compile.kernels.ref` by ``python/tests/test_kernel.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import OPS

#: Default tile length (elements) for the 1-D combine grid.  8192 f32 =
#: 32 KiB per operand; 3 operands → 96 KiB VMEM per grid step.
DEFAULT_TILE = 8192

#: Sub-lane alignment: TPU vector registers are (8, 128) f32, so tiles and
#: total lengths are kept multiples of 1024 to stay layout-friendly even
#: though interpret mode would accept anything.
ALIGN = 1024


def _binop(op: str):
    """The elementwise jnp binary op for operator name ``op``."""
    if op == "sum":
        return jnp.add
    if op == "prod":
        return jnp.multiply
    if op == "min":
        return jnp.minimum
    if op == "max":
        return jnp.maximum
    raise ValueError(f"unknown operator {op!r}; expected one of {OPS}")


def _combine_body(a_ref, b_ref, o_ref, *, op: str):
    """Pallas kernel body: one VMEM tile of ``o = a ⊕ b``."""
    o_ref[...] = _binop(op)(a_ref[...], b_ref[...])


def choose_tile(n: int, tile: int = DEFAULT_TILE) -> int:
    """Largest tile ≤ ``tile`` that divides ``n``.

    Bucket lengths produced by :mod:`compile.aot` are multiples of
    ``DEFAULT_TILE`` so this normally returns ``tile`` unchanged; for odd
    test shapes it falls back to the largest divisor, keeping the grid
    exact (no masking needed in the kernel body).
    """
    if n <= 0:
        raise ValueError(f"combine length must be positive, got {n}")
    t = min(tile, n)
    while n % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("op", "tile"))
def combine(a, b, *, op: str = "sum", tile: int = DEFAULT_TILE):
    """Elementwise ``a ⊕ b`` over 1-D arrays via the tiled Pallas kernel.

    Args:
      a, b: rank-1 arrays of equal shape and dtype.
      op: one of :data:`compile.kernels.ref.OPS`.
      tile: requested VMEM tile length; adjusted by :func:`choose_tile`.

    Returns:
      Rank-1 array ``a ⊕ b`` of the same shape/dtype.
    """
    if a.ndim != 1 or a.shape != b.shape:
        raise ValueError(f"combine expects equal 1-D shapes, got {a.shape} vs {b.shape}")
    n = a.shape[0]
    t = choose_tile(n, tile)
    grid = (n // t,)
    spec = pl.BlockSpec((t,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_combine_body, op=op),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), a.dtype),
        interpret=True,
    )(a, b)


def _fma_body(r_ref, t_ref, scale_ref, o_ref):
    """Fused ``o = r + scale * t`` tile — the weighted-combine variant used
    by the gradient-averaging path of the training driver (allreduce of
    gradients followed by division by the worker count is fused into the
    final combine instead of a separate scaling pass)."""
    o_ref[...] = r_ref[...] + scale_ref[0] * t_ref[...]


@functools.partial(jax.jit, static_argnames=("tile",))
def combine_scaled(r, t, scale, *, tile: int = DEFAULT_TILE):
    """``r + scale · t`` over 1-D arrays (scale is a scalar array).

    Used by the E2E training example to fold the ``1/p`` gradient averaging
    into the last combine of the allgather phase, saving one full pass over
    the gradient vector per step.
    """
    if r.ndim != 1 or r.shape != t.shape:
        raise ValueError(f"combine_scaled expects equal 1-D shapes, got {r.shape} vs {t.shape}")
    n = r.shape[0]
    tl = choose_tile(n, tile)
    spec = pl.BlockSpec((tl,), lambda i: (i,))
    scale_arr = jnp.asarray(scale, dtype=r.dtype).reshape((1,))
    return pl.pallas_call(
        _fma_body,
        grid=(n // tl,),
        in_specs=[spec, spec, pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), r.dtype),
        interpret=True,
    )(r, t, scale_arr)


def vmem_footprint_bytes(tile: int, dtype_bytes: int = 4, operands: int = 3) -> int:
    """Estimated VMEM bytes live per grid step (a, b, out tiles).

    Recorded in DESIGN.md §Perf; the perf pass asserts the default tile
    stays under the 192 KiB budget chosen there (conservative slice of a
    TensorCore's VMEM so several rounds can double-buffer).
    """
    return operands * tile * dtype_bytes
