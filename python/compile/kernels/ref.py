"""Pure-jnp correctness oracles for the Layer-1 Pallas kernels.

Everything the Pallas kernels in :mod:`compile.kernels.combine` compute must
be reproducible by the plain jax.numpy expressions here; pytest/hypothesis
(``python/tests/test_kernel.py``) enforces ``assert_allclose`` between the
two across a swept space of shapes, dtypes and operators.

The operators correspond to the commutative MPI reduction operators the
paper's Algorithm 1/2 are stated for (the paper assumes a commutative ⊕,
§2.1): MPI_SUM, MPI_PROD, MPI_MIN, MPI_MAX.
"""

from __future__ import annotations

import jax.numpy as jnp

#: Names of the supported commutative block-combine operators, in the order
#: they are assigned operator ids in the AOT manifest.
OPS = ("sum", "prod", "min", "max")


def combine_ref(a, b, op: str):
    """Elementwise ``a ⊕ b`` — reference semantics for one combine step.

    This is the partial-result update of Algorithm 1's inner loop,
    ``R[i] ← R[i] ⊕ T[i]``, flattened over a contiguous run of blocks (the
    paper's §3 notes that all sequences of blocks are consecutive in memory,
    so the per-round reduction is a single bulk elementwise operation).
    """
    if op == "sum":
        return a + b
    if op == "prod":
        return a * b
    if op == "min":
        return jnp.minimum(a, b)
    if op == "max":
        return jnp.maximum(a, b)
    raise ValueError(f"unknown operator {op!r}; expected one of {OPS}")


def reduce_blocks_ref(stack, op: str):
    """Reference reduction of a ``(k, n)`` stack of k blocks down to ``(n,)``.

    Equals ``blocks[0] ⊕ blocks[1] ⊕ … ⊕ blocks[k-1]``; used to check that
    arbitrary combine trees (any bracketing, any commutation) produced by the
    schedules agree with a canonical fold, which is exactly the
    commutativity/associativity contract the paper's algorithms rely on.
    """
    if op == "sum":
        return jnp.sum(stack, axis=0)
    if op == "prod":
        return jnp.prod(stack, axis=0)
    if op == "min":
        return jnp.min(stack, axis=0)
    if op == "max":
        return jnp.max(stack, axis=0)
    raise ValueError(f"unknown operator {op!r}; expected one of {OPS}")
