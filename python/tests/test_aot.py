"""AOT bridge tests: HLO text emission, manifest integrity, round-trip.

``--quick`` manifests (smallest bucket only) keep this fast; the full
artifact set is produced by ``make artifacts``.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels.ref import OPS, combine_ref


@pytest.fixture(scope="module")
def quick_manifest(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build_manifest(str(out), quick=True)
    with open(out / "manifest.json", "w") as f:
        json.dump(manifest, f)
    return str(out), manifest


def test_manifest_contents(quick_manifest):
    out, m = quick_manifest
    assert m["format"] == 1
    assert m["buckets"] == [aot.BUCKETS[0]]
    kinds = {e["kind"] for e in m["artifacts"]}
    assert kinds == {"combine", "combine_scaled", "mlp_loss_grad"}
    # one combine per op, one scaled, one mlp
    assert len(m["artifacts"]) == len(OPS) + 1 + 1
    for e in m["artifacts"]:
        path = os.path.join(out, e["file"])
        assert os.path.exists(path), e["file"]
        assert os.path.getsize(path) == e["bytes"]


def test_hlo_text_is_parseable_hlo(quick_manifest):
    """The artifacts are HLO *text* modules (ENTRY + computation), not
    StableHLO MLIR or serialized protos — the only format xla_extension
    0.5.1 accepts (see aot.py docstring)."""
    out, m = quick_manifest
    for e in m["artifacts"]:
        text = open(os.path.join(out, e["file"])).read()
        assert "HloModule" in text, e["file"]
        assert "ENTRY" in text, e["file"]
        assert "stablehlo" not in text, e["file"]


def test_combine_artifact_roundtrip_numerics(quick_manifest):
    """Execute the lowered combine artifact through jax's own runtime and
    compare with the oracle — proves lowering didn't change semantics.
    (The Rust PJRT round-trip is covered by rust/tests/runtime_*.rs.)"""
    n = aot.BUCKETS[0]
    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.standard_normal(n), jnp.float32)
    b = jnp.asarray(rng.standard_normal(n), jnp.float32)
    for op in OPS:
        compiled = model.lower_combine(op, n).compile()
        (got,) = compiled(a, b)
        np.testing.assert_allclose(np.asarray(got), np.asarray(combine_ref(a, b, op)), rtol=1e-6)


def test_mlp_artifact_entry_shapes(quick_manifest):
    out, m = quick_manifest
    (e,) = [e for e in m["artifacts"] if e["kind"] == "mlp_loss_grad"]
    p = model.mlp_param_count()
    assert e["n"] == p == m["mlp"]["params"]
    assert e["inputs"] == [[p], [model.MLP_BATCH, model.MLP_IN], [model.MLP_BATCH, model.MLP_OUT]]
    assert e["outputs"] == [[], [p]]


def test_digests_stable(quick_manifest):
    """Re-lowering produces byte-identical HLO (deterministic AOT) — this is
    what makes `make artifacts` reproducible and cache-friendly."""
    out, m = quick_manifest
    n = aot.BUCKETS[0]
    text = aot.to_hlo_text(model.lower_combine("sum", n))
    (e,) = [x for x in m["artifacts"] if x["kind"] == "combine" and x["op"] == "sum"]
    assert open(os.path.join(out, e["file"])).read() == text
