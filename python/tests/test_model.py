"""Layer-2 correctness: MLP loss/grad graph and lowering shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model

SETTINGS = settings(max_examples=10, deadline=None)


def _toy_data(seed=0, batch=model.MLP_BATCH):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((batch, model.MLP_IN)).astype(np.float32)
    w = rng.standard_normal((model.MLP_IN, model.MLP_OUT)).astype(np.float32)
    y = np.tanh(x @ w) * 0.5
    return jnp.asarray(x), jnp.asarray(y)


def test_param_count_matches_flat_vector():
    p = model.mlp_init(0)
    assert p.shape == (model.mlp_param_count(),)
    assert p.dtype == jnp.float32
    # Explicit arithmetic from the architecture constants.
    d, h, o = model.MLP_IN, model.MLP_HIDDEN, model.MLP_OUT
    assert model.mlp_param_count() == d * h + h + h * h + h + h * o + o


def test_loss_and_grad_shapes():
    params = model.mlp_init(1)
    x, y = _toy_data(1)
    loss, grad = model.mlp_loss_and_grad(params, x, y)
    assert loss.shape == ()
    assert grad.shape == params.shape
    assert bool(jnp.isfinite(loss))
    assert bool(jnp.all(jnp.isfinite(grad)))


def test_grad_matches_finite_differences():
    """Spot-check autodiff against central differences on a few coords."""
    params = model.mlp_init(2)
    x, y = _toy_data(2, batch=8)
    _, grad = model.mlp_loss_and_grad(params, x, y)
    eps = 1e-3
    rng = np.random.default_rng(0)
    for i in rng.integers(0, params.shape[0], size=5):
        e = jnp.zeros_like(params).at[i].set(eps)
        lp = model.mlp_loss(params + e, x, y)
        lm = model.mlp_loss(params - e, x, y)
        fd = (lp - lm) / (2 * eps)
        np.testing.assert_allclose(float(grad[i]), float(fd), rtol=5e-2, atol=5e-4)


def test_sgd_descends():
    """A few SGD steps on the toy problem must reduce the loss — the same
    signal the E2E driver logs, in miniature."""
    params = model.mlp_init(3)
    x, y = _toy_data(3)
    l0, _ = model.mlp_loss_and_grad(params, x, y)
    lr = 0.05
    for _ in range(20):
        _, g = model.mlp_loss_and_grad(params, x, y)
        params = params - lr * g
    l1, _ = model.mlp_loss_and_grad(params, x, y)
    assert float(l1) < float(l0) * 0.9, (float(l0), float(l1))


@SETTINGS
@given(seed=st.integers(0, 2**31 - 1))
def test_data_parallel_grad_equals_full_batch_grad(seed):
    """Averaging per-shard gradients (what the allreduce driver computes)
    equals the full-batch gradient for a mean loss over equal shards —
    the identity the E2E example's convergence relies on."""
    params = model.mlp_init(4)
    rng = np.random.default_rng(seed)
    batch, shards = 16, 4
    x = jnp.asarray(rng.standard_normal((batch, model.MLP_IN)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((batch, model.MLP_OUT)), jnp.float32)
    _, g_full = model.mlp_loss_and_grad(params, x, y)
    per = batch // shards
    gs = []
    for s in range(shards):
        _, g = model.mlp_loss_and_grad(params, x[s * per : (s + 1) * per], y[s * per : (s + 1) * per])
        gs.append(g)
    g_avg = sum(gs) / shards
    np.testing.assert_allclose(np.asarray(g_avg), np.asarray(g_full), rtol=1e-4, atol=1e-6)


def test_lowering_shapes():
    """The lowered MLP artifact has the input/output signature the manifest
    advertises and the Rust runtime marshals."""
    lowered = model.lower_mlp()
    text = lowered.as_text()
    assert "jit" in text or "func" in text  # sanity: real MLIR came out
    p = model.mlp_param_count()
    comp = lowered.compile()
    out = comp(model.mlp_init(0), *_toy_data(0))
    assert out[0].shape == () and out[1].shape == (p,)


def test_forward_unflatten_consistency():
    """Zero weights ⇒ zero output; bias-only params propagate."""
    p = jnp.zeros((model.mlp_param_count(),), jnp.float32)
    x, _ = _toy_data(5)
    out = model.mlp_forward(p, x)
    np.testing.assert_array_equal(np.asarray(out), 0.0)
