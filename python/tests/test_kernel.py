"""Layer-1 correctness: Pallas combine kernels vs the pure-jnp oracle.

This is the core numeric signal for the whole stack: the HLO the Rust
runtime executes is lowered from exactly these kernels, so agreement with
``ref.py`` here plus the HLO round-trip test in ``test_aot.py`` covers the
compute half of Corollary 1's γ term.

Hypothesis sweeps shapes (aligned buckets, odd lengths, prime lengths),
dtypes and operators; regression tests pin the bucket shapes the AOT
manifest actually ships.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    DEFAULT_TILE,
    OPS,
    choose_tile,
    combine,
    combine_ref,
    combine_scaled,
    reduce_blocks_ref,
)
from compile.kernels.combine import vmem_footprint_bytes

# Interpret-mode pallas is slow; keep example counts moderate but meaningful.
SETTINGS = settings(max_examples=25, deadline=None)

DTYPES = (jnp.float32, jnp.int32)


def _arr(rng, n, dtype):
    if dtype == jnp.int32:
        return jnp.asarray(rng.integers(-50, 50, size=n), dtype=dtype)
    return jnp.asarray(rng.standard_normal(n), dtype=dtype)


# ---------------------------------------------------------------------------
# Hypothesis sweeps
# ---------------------------------------------------------------------------


@SETTINGS
@given(
    n=st.integers(min_value=1, max_value=4096),
    op=st.sampled_from(OPS),
    dtype_ix=st.integers(0, len(DTYPES) - 1),
    seed=st.integers(0, 2**31 - 1),
)
def test_combine_matches_ref_swept(n, op, dtype_ix, seed):
    """combine == ref for arbitrary lengths, ops, dtypes, data."""
    dtype = DTYPES[dtype_ix]
    rng = np.random.default_rng(seed)
    a, b = _arr(rng, n, dtype), _arr(rng, n, dtype)
    got = combine(a, b, op=op)
    want = combine_ref(a, b, op)
    assert got.dtype == a.dtype
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@SETTINGS
@given(
    n=st.integers(min_value=1, max_value=2048),
    scale=st.floats(min_value=-4.0, max_value=4.0, allow_nan=False),
    seed=st.integers(0, 2**31 - 1),
)
def test_combine_scaled_matches_fma(n, scale, seed):
    """combine_scaled(r, t, s) == r + s*t."""
    rng = np.random.default_rng(seed)
    r = jnp.asarray(rng.standard_normal(n), jnp.float32)
    t = jnp.asarray(rng.standard_normal(n), jnp.float32)
    got = combine_scaled(r, t, scale)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(r) + np.float32(scale) * np.asarray(t), rtol=1e-5, atol=1e-6
    )


@SETTINGS
@given(n=st.integers(min_value=1, max_value=1 << 20), tile=st.integers(1, 16384))
def test_choose_tile_divides_and_bounded(n, tile):
    """choose_tile returns a divisor of n that never exceeds the request."""
    t = choose_tile(n, tile)
    assert 1 <= t <= max(1, min(tile, n))
    assert n % t == 0


@SETTINGS
@given(
    n=st.integers(min_value=1, max_value=512),
    op=st.sampled_from(OPS),
    seed=st.integers(0, 2**31 - 1),
)
def test_combine_commutative(n, op, seed):
    """The kernel realizes a commutative ⊕ (the paper's §2.1 assumption) —
    exact commutativity holds elementwise for all four ops in IEEE f32."""
    rng = np.random.default_rng(seed)
    a, b = _arr(rng, n, jnp.float32), _arr(rng, n, jnp.float32)
    np.testing.assert_array_equal(np.asarray(combine(a, b, op=op)), np.asarray(combine(b, a, op=op)))


@SETTINGS
@given(
    k=st.integers(min_value=1, max_value=8),
    n=st.integers(min_value=1, max_value=256),
    op=st.sampled_from(OPS),
    seed=st.integers(0, 2**31 - 1),
    order_seed=st.integers(0, 2**31 - 1),
)
def test_fold_order_independent_for_exact_ops(k, n, op, seed, order_seed):
    """Folding k blocks through the kernel in *any* order matches the
    canonical reduction for min/max (exact) and integer-valued sum/prod
    (exact in f32 within range) — the algebraic property Theorem 1's
    spanning-forest argument relies on."""
    rng = np.random.default_rng(seed)
    if op in ("sum", "prod"):
        # Integer-valued f32 keeps sum/prod exact; bound magnitude for prod.
        hi = 4 if op == "prod" else 100
        stack = rng.integers(1, hi, size=(k, n)).astype(np.float32)
    else:
        stack = rng.standard_normal((k, n)).astype(np.float32)
    order = np.random.default_rng(order_seed).permutation(k)
    acc = jnp.asarray(stack[order[0]])
    for i in order[1:]:
        acc = combine(acc, jnp.asarray(stack[i]), op=op)
    want = reduce_blocks_ref(jnp.asarray(stack), op)
    np.testing.assert_allclose(np.asarray(acc), np.asarray(want), rtol=1e-6)


# ---------------------------------------------------------------------------
# Pinned regression cases (the shipped bucket shapes)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1024, 8192, 65536])
@pytest.mark.parametrize("op", OPS)
def test_bucket_shapes(n, op):
    """Exactly the (op, bucket) combinations the AOT manifest ships."""
    rng = np.random.default_rng(n)
    a = jnp.asarray(rng.standard_normal(n), jnp.float32)
    b = jnp.asarray(rng.standard_normal(n), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(combine(a, b, op=op)), np.asarray(combine_ref(a, b, op)), rtol=1e-6
    )


def test_special_values_min_max():
    """min/max handle infinities; sum handles signed zeros."""
    a = jnp.asarray([np.inf, -np.inf, 0.0, -0.0], jnp.float32)
    b = jnp.asarray([1.0, 1.0, -0.0, 0.0], jnp.float32)
    np.testing.assert_array_equal(np.asarray(combine(a, b, op="min")), [1.0, -np.inf, -0.0, -0.0])
    np.testing.assert_array_equal(np.asarray(combine(a, b, op="max")), [np.inf, 1.0, 0.0, 0.0])
    np.testing.assert_array_equal(np.asarray(combine(a, b, op="sum")), [np.inf, -np.inf, 0.0, 0.0])


def test_errors():
    a = jnp.zeros((4,), jnp.float32)
    with pytest.raises(ValueError):
        combine(a, jnp.zeros((5,), jnp.float32), op="sum")
    with pytest.raises(ValueError):
        combine(a, a, op="bogus")
    with pytest.raises(ValueError):
        choose_tile(0)


def test_vmem_budget():
    """DESIGN.md §Perf budget: default tile keeps 3 live f32 buffers under
    192 KiB of VMEM."""
    assert vmem_footprint_bytes(DEFAULT_TILE) <= 192 * 1024
    assert DEFAULT_TILE % 1024 == 0  # lane-layout friendly


def test_grid_actually_tiles():
    """A length spanning multiple tiles exercises the BlockSpec grid (not a
    single degenerate block)."""
    n = DEFAULT_TILE * 3
    a = jnp.arange(n, dtype=jnp.float32)
    b = jnp.full((n,), 2.0, jnp.float32)
    np.testing.assert_allclose(np.asarray(combine(a, b, op="sum")), np.arange(n) + 2.0)
