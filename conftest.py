"""Pytest shim: make `pytest python/tests/` work from the repository root.

The python package root is `python/` (tests import `compile.*`), so put it
on sys.path regardless of the invocation directory.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
